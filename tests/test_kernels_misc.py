"""Sweep tests: consensus_mix + rmsnorm kernels vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.compressors import StochasticQuantizer
from repro.core import topology as tp
from repro.core.consensus import collapse_mixing
from repro.kernels import consensus_mix_pytree, ops
from repro.kernels.consensus_mix import (consensus_mix_2d,
                                         quantized_consensus_mix_2d)
from repro.kernels.ref import consensus_mix_ref, rmsnorm_ref

KEY = jax.random.key(11)


@pytest.mark.parametrize("m,d,block", [
    (2, 64, 32), (5, 1000, 128), (8, 4096, 2048), (16, 257, 64),
])
def test_consensus_mix_2d(m, d, block):
    a = jnp.asarray(collapse_mixing(
        tp.metropolis_weights(tp.ring_graph(m)), 7), jnp.float32)
    w = jax.random.normal(KEY, (m, d))
    out = consensus_mix_2d(a, w, block_d=block)
    ref = consensus_mix_ref(a, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,d,bits,chunk,block", [
    (5, 1024, 8, 128, 512),     # multi-tile, multi-chunk per tile
    (4, 1000, 8, 256, 512),     # ragged tail
    (3, 130, 4, 64, 128),       # int4
    (6, 37, 8, 256, 2048),      # single partial chunk
])
def test_quantized_consensus_mix_matches_compressor_oracle(m, d, bits,
                                                           chunk, block):
    """The fused quantize->mix->dequantize kernel equals the composition of
    the comm-subsystem wire round-trip (same dither) and the dense mix."""
    a = jnp.asarray(collapse_mixing(
        tp.metropolis_weights(tp.ring_graph(m)), 7), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, d), (m, d)) * 3
    u = jax.random.uniform(jax.random.fold_in(KEY, d + 1), (m, d))
    out = quantized_consensus_mix_2d(a, w, u, bits=bits, chunk=chunk,
                                     block_d=block)
    q = StochasticQuantizer(bits=bits, chunk=chunk)
    ref = a @ q.decompress(q.compress(w, dither=u), d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


def test_quantized_consensus_mix_validates():
    a = jnp.eye(2)
    w = jnp.ones((2, 8))
    with pytest.raises(ValueError, match="bits"):
        quantized_consensus_mix_2d(a, w, w, bits=3)
    with pytest.raises(ValueError, match="divide"):
        quantized_consensus_mix_2d(a, w, w, chunk=3, block_d=8)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_consensus_mix_dtypes(dtype):
    m = 4
    a = jnp.asarray(collapse_mixing(
        tp.metropolis_weights(tp.complete_graph(m)), 3), jnp.float32)
    w = jax.random.normal(KEY, (m, 512)).astype(dtype)
    out = consensus_mix_2d(a, w, block_d=128)
    assert out.dtype == dtype
    ref = consensus_mix_ref(a, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(ref, jnp.float32),
                               rtol=tol, atol=tol)


def test_consensus_mix_pytree_roundtrip():
    m = 5
    a = jnp.asarray(collapse_mixing(
        tp.metropolis_weights(tp.line_graph(m)), 9), jnp.float32)
    kw, kb, kx = jax.random.split(KEY, 3)
    tree = {"w": jax.random.normal(kw, (m, 17, 3)),
            "b": jax.random.normal(kb, (m, 5)),
            "nested": {"x": jax.random.normal(kx, (m, 2, 2, 2))}}
    out = consensus_mix_pytree(a, tree, block_d=16)
    for lo, li in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        ref = consensus_mix_ref(a, li.reshape(m, -1)).reshape(li.shape)
        np.testing.assert_allclose(np.asarray(lo), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("rows,d,block", [
    (32, 128, 8), (100, 256, 32), (256, 960, 256), (7, 64, 8),
])
def test_rmsnorm_kernel(rows, d, block):
    kx, ks = jax.random.split(KEY)
    x = jax.random.normal(kx, (rows, d))
    scale = jax.random.normal(ks, (d,))
    out = ops.rmsnorm(x, scale, block_rows=block)
    ref = rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_rmsnorm_multidim_and_bf16():
    x = jax.random.normal(KEY, (2, 3, 5, 128)).astype(jnp.bfloat16)
    scale = jnp.ones((128,), jnp.bfloat16)
    out = ops.rmsnorm(x, scale)
    ref = rmsnorm_ref(x, scale)
    assert out.shape == x.shape and out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(ref, jnp.float32),
                               rtol=2e-2, atol=2e-2)
