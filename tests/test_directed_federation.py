"""Directed gossip: row-stochastic mixing is biased, push-sum is not.

Covers the directed topology layer (orientation, asymmetric degradation,
out-degree weights, strong connectivity), the push-sum consensus
primitives and their invariants (weights positive / sum to M, exact
degeneration to symmetric gossip), the DFLConfig(mixing=...) paths, and
the engine's weight reset on server drop/rejoin."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, FLTopology, FaultEvent, FaultSchedule,
                        SigmaTracker, TopologySchedule, build_dfl_epoch_step,
                        init_dfl_state, make_engine)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd


def _skewed_digraph(m=5):
    """Directed ring + a chord out of node 0: strongly connected with
    unequal out-degrees, so the out-degree matrix is row- but NOT doubly
    stochastic and its Perron vector is provably non-uniform."""
    adj = tp.directed_ring(m)
    adj[0, 2] = True
    return adj


def _tree(m, key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 4, 3)),
            "b": jax.random.normal(k2, (m, 7))}


# ---------------------------------------------------------------------------
# directed topology layer
# ---------------------------------------------------------------------------


def test_directed_ring_and_strong_connectivity():
    adj = tp.directed_ring(5)
    assert tp.is_directed(adj)
    assert tp.is_strongly_connected(adj)
    # removing one link of a directed cycle kills strong connectivity
    broken = adj.copy()
    broken[1, 2] = False
    assert not tp.is_strongly_connected(broken)
    # undirected delegates to plain connectivity
    assert tp.is_strongly_connected(tp.ring_graph(5))
    assert not tp.is_strongly_connected(np.zeros((3, 3), bool))


def test_random_orientation_repair_and_determinism():
    base = tp.complete_graph(6)
    for seed in range(5):
        adj = tp.random_orientation(base, np.random.default_rng(seed))
        assert tp.is_strongly_connected(adj)
        # orientation only uses base edges
        assert not (adj & ~(base | base.T)).any()
    a1 = tp.random_orientation(base, np.random.default_rng(3))
    a2 = tp.random_orientation(base, np.random.default_rng(3))
    np.testing.assert_array_equal(a1, a2)


def test_random_direction_drop_repairs_to_strong_connectivity():
    base = tp.ring_graph(6)
    for seed in range(5):
        adj = tp.random_direction_drop(base, 0.5,
                                       np.random.default_rng(seed))
        assert tp.is_strongly_connected(adj)
        assert not (adj & ~(base | base.T)).any()
    # with repair off, heavy drop rates may disconnect — and a drop rate of
    # 1 with repair must still return something strongly connected
    adj = tp.random_direction_drop(base, 1.0, np.random.default_rng(0),
                                   ensure_strong=True)
    assert tp.is_strongly_connected(adj)
    # on an already-directed base, degradation must never resurrect a
    # reverse link the base graph does not have
    dbase = _skewed_digraph()
    for seed in range(5):
        adj = tp.random_direction_drop(dbase, 0.5,
                                       np.random.default_rng(seed))
        assert not (adj & ~dbase).any()
    np.testing.assert_array_equal(
        tp.random_direction_drop(dbase, 0.0, np.random.default_rng(0)),
        dbase)


def test_weaken_directed_links_row_stochastic_preserved():
    """Directed straggler: weakening a single DIRECTION keeps rows summing
    to 1 (mass returns to the SENDER's self-loop), leaves the reverse
    direction untouched, and rejects self-loops / bad factors."""
    adj = _skewed_digraph()
    a = tp.out_degree_weights(adj)
    out = tp.weaken_directed_links(a, [(0, 1)], 0.8)
    np.testing.assert_allclose(out.sum(1), 1.0)
    np.testing.assert_allclose(out[0, 1], 0.2 * a[0, 1])
    np.testing.assert_allclose(out[0, 0], a[0, 0] + 0.8 * a[0, 1])
    np.testing.assert_allclose(out[1], a[1])      # reverse side untouched
    tp.check_row_stochastic(out, adj)
    with pytest.raises(ValueError, match="self-loop"):
        tp.weaken_directed_links(a, [(2, 2)], 0.5)
    with pytest.raises(ValueError, match="factor"):
        tp.weaken_directed_links(a, [(0, 1)], 1.5)


def test_asymmetric_schedule_weaken_emits_row_stochastic():
    """TopologySchedule(kind='asymmetric', weaken=...) — the directed
    counterpart of the straggler schedule: emitted matrices stay valid
    row-stochastic push-sum operators and genuinely differ from the
    unweakened ones."""
    topo = FLTopology(num_servers=5, clients_per_server=2, t_client=2,
                      t_server=4, graph_kind="ring", mixing="out_degree")
    plain = TopologySchedule(kind="asymmetric", drop_prob=0.3, seed=3)
    weak = TopologySchedule(kind="asymmetric", drop_prob=0.3, weaken=0.9,
                            n_weak=2, seed=3)
    changed = 0
    for epoch in range(6):
        a_w = weak.mixing(topo, epoch)
        tp.check_row_stochastic(a_w, atol=1e-9)
        changed += not np.allclose(a_w, plain.mixing(topo, epoch))
    assert changed >= 4


def test_push_sum_unbiased_under_directed_weakening(rng_key):
    """Push-sum's unbiasedness survives per-direction weakening: mixing a
    tree with weakened row-stochastic matrices for many rounds drives every
    server's ratio read-out to the exact uniform initial mean (the weakened
    transpose is still column stochastic, so sums are preserved)."""
    topo = FLTopology(num_servers=5, clients_per_server=2, t_client=2,
                      t_server=6, graph_kind="ring", mixing="out_degree")
    sched = TopologySchedule(kind="asymmetric", drop_prob=0.4, weaken=0.8,
                             n_weak=3, seed=9)
    tree = _tree(5, rng_key)
    want = {k: np.asarray(v).mean(axis=0) for k, v in tree.items()}
    state = cns.init_push_sum(tree)
    for epoch in range(30):
        a = jnp.asarray(sched.mixing(topo, epoch), jnp.float32)
        state = cns.gossip_push_sum(a, state, topo.t_server)
        w = np.asarray(state.weight)
        assert (w > 0).all()
        np.testing.assert_allclose(w.sum(), 5.0, rtol=1e-5)
    ratio = state.ratio()
    for k in tree:
        got = np.asarray(ratio[k])
        for i in range(5):
            np.testing.assert_allclose(got[i], want[k], rtol=2e-4,
                                       atol=2e-4)


def test_out_degree_weights_row_stochastic_not_doubly():
    adj = _skewed_digraph()
    a = tp.out_degree_weights(adj)
    tp.check_row_stochastic(a, adj)
    np.testing.assert_allclose(a.sum(1), 1.0, atol=1e-12)
    assert not np.allclose(a.sum(0), 1.0)      # NOT doubly stochastic
    pi = tp.perron_weights(a)
    assert pi.min() > 0 and abs(pi.sum() - 1.0) < 1e-9
    assert np.abs(pi - 1.0 / 5).max() > 0.02   # non-uniform Perron vector
    # plain directed ring: every out-degree equal -> doubly stochastic,
    # uniform Perron weights
    a_ring = tp.out_degree_weights(tp.directed_ring(5))
    np.testing.assert_allclose(a_ring.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(tp.perron_weights(a_ring), 0.2, atol=1e-9)


def test_check_row_stochastic_rejects_bad_matrices():
    with pytest.raises(ValueError, match="rows"):
        tp.check_row_stochastic(np.array([[0.5, 0.4], [0.5, 0.5]]))
    with pytest.raises(ValueError, match="non-negative"):
        tp.check_row_stochastic(np.array([[1.5, -0.5], [0.5, 0.5]]))
    with pytest.raises(ValueError, match="diagonal"):
        tp.check_row_stochastic(np.array([[0.0, 1.0], [0.5, 0.5]]))
    with pytest.raises(ValueError, match="non-edge"):
        tp.check_row_stochastic(
            np.array([[0.5, 0.5], [0.5, 0.5]]),
            np.array([[False, True], [False, False]]))


def test_sigma_push_sum_contracts_where_sigma_a_does_not():
    a = tp.out_degree_weights(_skewed_digraph())
    # the ratio map contracts to exact averaging...
    assert tp.sigma_push_sum(a, 50) < 1e-5
    assert tp.sigma_push_sum(a, 50) < tp.sigma_push_sum(a, 5)
    # ...while the raw row-stochastic power converges to 1 pi' != 11'/M
    assert tp.sigma_a(a, 50) > 0.1


def test_fltopology_directed_validation_and_sigma():
    topo = FLTopology(num_servers=5, clients_per_server=2, t_client=3,
                      t_server=25, graph_kind="directed_ring",
                      mixing="out_degree")
    assert topo.directed
    tp.check_row_stochastic(topo.mixing_matrix(), topo.adjacency())
    assert topo.sigma() < 0.1          # push-sum contraction, not sigma_a
    with pytest.raises(ValueError, match="directed"):
        FLTopology(num_servers=5, clients_per_server=2, t_client=3,
                   t_server=2, graph_kind="directed_ring")
    with pytest.raises(ValueError, match="unknown mixing"):
        FLTopology(num_servers=3, clients_per_server=2, t_client=3,
                   t_server=2, mixing="bogus")
    # drop_server on a directed family falls back to a DIRECTED ring
    new, keep = topo.drop_server(2)
    assert new.num_servers == 4 and new.directed


# ---------------------------------------------------------------------------
# push-sum consensus primitives
# ---------------------------------------------------------------------------


def test_push_sum_matches_gossip_on_doubly_stochastic(rng_key):
    """Degeneration: with Eq. 6 weights the push-sum weight stays 1 and the
    ratio equals plain gossip to fp32 tolerance."""
    m, t_s = 5, 9
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    tree = _tree(m, rng_key)
    ps = cns.gossip_push_sum(a, cns.init_push_sum(tree), t_s)
    ref = cns.gossip_scan(a, tree, t_s)
    np.testing.assert_allclose(np.asarray(ps.weight), 1.0, rtol=1e-5)
    for l1, l2 in zip(jax.tree.leaves(ps.ratio()), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-5, atol=2e-5)


def test_push_sum_unbiased_where_naive_row_stochastic_is_biased(rng_key):
    """The tentpole claim, at the primitive level: on a skewed digraph,
    naive gossip converges to the Perron-weighted average pi'x (NOT the
    mean), push-sum's ratio converges to the exact mean."""
    a_np = tp.out_degree_weights(_skewed_digraph())
    pi = tp.perron_weights(a_np)
    a = jnp.asarray(a_np, jnp.float32)
    x = jax.random.normal(rng_key, (5, 11))
    mean = np.asarray(x).mean(0)
    biased = pi @ np.asarray(x)
    gap = np.abs(biased - mean).max()
    assert gap > 0.01                                 # the bias is real

    naive = np.asarray(cns.gossip_scan(a, {"w": x}, 200)["w"])
    np.testing.assert_allclose(naive, np.broadcast_to(biased, naive.shape),
                               atol=1e-4)            # lands on pi'x ...
    assert np.abs(naive - mean).max() > 0.5 * gap    # ... away from mean

    ps = cns.gossip_push_sum(a, cns.init_push_sum({"w": x}), 200)
    ratio = np.asarray(ps.ratio()["w"])
    np.testing.assert_allclose(ratio, np.broadcast_to(mean, ratio.shape),
                               atol=1e-4)            # unbiased


def test_push_sum_weight_invariants_across_rounds(rng_key):
    """Weights stay positive and sum to M at every round."""
    m = 5
    a = jnp.asarray(tp.out_degree_weights(_skewed_digraph()), jnp.float32)
    tree = {"w": jax.random.normal(rng_key, (m, 3))}
    for t in range(1, 12):
        ps = cns.gossip_push_sum(a, cns.init_push_sum(tree), t)
        w = np.asarray(ps.weight)
        assert (w > 0).all(), (t, w)
        np.testing.assert_allclose(w.sum(), m, rtol=1e-5)
        # numerator sum is preserved too (column-stochastic mixing)
        np.testing.assert_allclose(np.asarray(ps.values["w"]).sum(0),
                                   np.asarray(tree["w"]).sum(0), rtol=1e-4,
                                   atol=1e-4)


def test_push_sum_tv_matches_fixed_and_stays_unbiased(rng_key):
    m = 5
    a = jnp.asarray(tp.out_degree_weights(_skewed_digraph()), jnp.float32)
    tree = {"w": jax.random.normal(rng_key, (m, 7))}
    stack = jnp.broadcast_to(a, (6, m, m))
    tv = cns.gossip_push_sum_tv(stack, cns.init_push_sum(tree))
    fixed = cns.gossip_push_sum(a, cns.init_push_sum(tree), 6)
    np.testing.assert_array_equal(np.asarray(tv.weight),
                                  np.asarray(fixed.weight))
    np.testing.assert_array_equal(np.asarray(tv.values["w"]),
                                  np.asarray(fixed.values["w"]))
    # genuinely time-varying digraphs: many rounds of alternating graphs
    # still read out the exact mean
    mats = [tp.out_degree_weights(_skewed_digraph()),
            tp.out_degree_weights(tp.directed_ring(m)),
            tp.out_degree_weights(tp.random_orientation(
                tp.complete_graph(m), np.random.default_rng(1)))]
    stack = jnp.asarray(np.stack([mats[i % 3] for i in range(60)]),
                        jnp.float32)
    out = cns.gossip_push_sum_tv(stack, cns.init_push_sum(tree))
    mean = np.asarray(tree["w"]).mean(0)
    np.testing.assert_allclose(np.asarray(out.ratio()["w"]),
                               np.broadcast_to(mean, (m, 7)), atol=1e-4)


def test_sigma_tracker_push_sum_mode():
    a = tp.out_degree_weights(_skewed_digraph())
    tr = SigmaTracker(5, mode="push_sum")
    sig = [tr.update(a, 10) for _ in range(3)]
    assert sig[0] > sig[1] > sig[2]
    assert sig[-1] == pytest.approx(tp.sigma_push_sum(a, 30), abs=1e-9)
    # average mode would (wrongly) report no contraction here
    tr_avg = SigmaTracker(5, mode="average")
    assert tr_avg.update(a, 30) > 0.1
    with pytest.raises(ValueError, match="mode"):
        SigmaTracker(5, mode="bogus")


# ---------------------------------------------------------------------------
# DFLConfig(mixing=...) paths
# ---------------------------------------------------------------------------


def _directed_topo(t_c=5, t_s=8):
    return FLTopology(num_servers=5, clients_per_server=3, t_client=t_c,
                      t_server=t_s, graph_kind="random_orientation",
                      mixing="out_degree")


def test_mixing_validation():
    topo = _directed_topo()
    loss = lambda w, b, r: (jnp.zeros(()), {})
    with pytest.raises(ValueError, match="unknown mixing"):
        build_dfl_epoch_step(DFLConfig(topology=topo, mixing="bogus"),
                             loss, sgd(1e-3))
    with pytest.raises(ValueError, match="Perron-weighted"):
        build_dfl_epoch_step(DFLConfig(topology=topo), loss, sgd(1e-3))
    with pytest.raises(ValueError, match="undefined"):
        build_dfl_epoch_step(
            DFLConfig(topology=topo, mixing="push_sum",
                      consensus_mode="chebyshev"), loss, sgd(1e-3))
    # an injected backend without a directed update (exact_mean ignores A)
    # is rejected the same way as the consensus_mode string would be
    backend = cns.make_backend("exact_mean", topo.mixing_matrix(),
                               topo.t_server)
    with pytest.raises(ValueError, match="undefined"):
        build_dfl_epoch_step(
            DFLConfig(topology=topo, mixing="push_sum",
                      consensus_backend=backend), loss, sgd(1e-3))
    with pytest.raises(ValueError, match="asymmetric"):
        make_engine(FLTopology(num_servers=3, clients_per_server=2,
                               t_client=2, t_server=2), loss, sgd(1e-3),
                    topology_schedule=TopologySchedule(kind="asymmetric",
                                                       drop_prob=0.3))


def test_push_sum_epoch_step_matches_symmetric_on_undirected():
    """mixing='push_sum' over a doubly-stochastic topology reproduces the
    symmetric epoch step to fp32 tolerance (and carries unit weights)."""
    topo = FLTopology(num_servers=4, clients_per_server=3, t_client=5,
                      t_server=6, graph_kind="ring")
    task = make_regression_task(topo, seed=0)
    opt = sgd(1e-3)
    step_sym = jax.jit(build_dfl_epoch_step(
        DFLConfig(topology=topo), task["loss_fn"], opt))
    cfg_ps = DFLConfig(topology=topo, mixing="push_sum")
    step_ps = jax.jit(build_dfl_epoch_step(cfg_ps, task["loss_fn"], opt))
    st_sym = init_dfl_state(DFLConfig(topology=topo), jnp.zeros((2,)), opt,
                            jax.random.key(0))
    st_ps = init_dfl_state(cfg_ps, jnp.zeros((2,)), opt, jax.random.key(0))
    assert st_ps.psum_weight.shape == (4,) and st_sym.psum_weight is None
    for _ in range(3):
        st_sym, _ = step_sym(st_sym, task["batches"])
        st_ps, _ = step_ps(st_ps, task["batches"])
    np.testing.assert_allclose(np.asarray(st_ps.client_params),
                               np.asarray(st_sym.client_params),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(st_ps.psum_weight), 1.0,
                               rtol=1e-5)


def test_push_sum_collapsed_matches_gossip_rounds():
    """consensus_mode='collapsed' under push_sum (one round with A^{T_S})
    equals the T_S-round schedule."""
    topo = _directed_topo()
    task = make_regression_task(topo, seed=1)
    opt = sgd(1e-3)
    outs = {}
    for mode in ("gossip", "collapsed"):
        cfg = DFLConfig(topology=topo, mixing="push_sum",
                        consensus_mode=mode)
        step = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"], opt))
        st = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
        st, _ = step(st, task["batches"])
        outs[mode] = st
    np.testing.assert_allclose(np.asarray(outs["gossip"].client_params),
                               np.asarray(outs["collapsed"].client_params),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(outs["gossip"].psum_weight),
                               np.asarray(outs["collapsed"].psum_weight),
                               rtol=2e-5)


def test_dfl_bias_end_to_end():
    """Through the full DFL stack with per-server concept shift: naive
    row-stochastic training is measurably biased away from w*, push-sum is
    not (it matches the symmetric fixed point)."""
    topo = FLTopology(num_servers=5, clients_per_server=3, t_client=15,
                      t_server=25, graph_kind="random_orientation",
                      mixing="out_degree")
    task = make_regression_task(topo, RegressionSpec(concept_shift=2.0),
                                seed=0)
    gamma = 0.4 / (9.0 * topo.t_client)
    errs = {}
    for mixing in ("push_sum", "row_stochastic"):
        cfg = DFLConfig(topology=topo, mixing=mixing)
        step = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"],
                                            sgd(gamma)))
        st = init_dfl_state(cfg, jnp.zeros((2,)), sgd(gamma),
                            jax.random.key(0))
        for _ in range(60):
            st, _ = step(st, task["batches"])
        servers = np.asarray(st.client_params[:, 0])
        errs[mixing] = float(
            np.linalg.norm(servers - task["w_star"], axis=-1).max())
    assert errs["row_stochastic"] > 1.5 * errs["push_sum"], errs
    assert errs["push_sum"] < 0.2, errs


# ---------------------------------------------------------------------------
# engine: asymmetric schedules and weight reset on surgery
# ---------------------------------------------------------------------------


def test_engine_asymmetric_push_sum_converges():
    base = FLTopology(num_servers=5, clients_per_server=3, t_client=15,
                      t_server=12, graph_kind="ring")
    task = make_regression_task(base, seed=0)
    gamma = 0.4 / (9.0 * base.t_client)
    engine = make_engine(base, task["loss_fn"], sgd(gamma),
                         mixing="push_sum",
                         topology_schedule=TopologySchedule(
                             kind="asymmetric", drop_prob=0.4, seed=7))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    state, hist = engine.run(state, 60, task["batch_fn"])
    servers = np.asarray(state.client_params[:, 0])
    err = float(np.linalg.norm(servers - task["w_star"], axis=-1).max())
    assert err < 0.3, err
    assert hist["sigma_prod"][-1] < 1e-6           # push-sum tracker mode
    assert 0.0 < hist["psum_min_weight"][-1] <= 1.0 + 1e-6


def test_engine_drop_rejoin_resets_push_sum_weight():
    base = FLTopology(num_servers=4, clients_per_server=2, t_client=4,
                      t_server=6, graph_kind="ring")
    task = make_regression_task(base, seed=0)
    gamma = 1e-3
    engine = make_engine(base, task["loss_fn"], sgd(gamma),
                         mixing="push_sum",
                         topology_schedule=TopologySchedule(
                             kind="asymmetric", drop_prob=0.5, seed=3),
                         faults=FaultSchedule((FaultEvent(2, "drop", 1),
                                               FaultEvent(4, "rejoin", 1))))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    # run to just before the drop; weights are generally non-uniform now
    for epoch in range(2):
        state, _ = engine.run_epoch(state, epoch, task["batch_fn"])
    assert state.psum_weight.shape == (4,)
    # surgery itself resets the weights to ones at the NEW federation size
    surgically = engine.apply_faults(state, 2)
    assert surgically.psum_weight.shape == (3,)
    np.testing.assert_array_equal(np.asarray(surgically.psum_weight), 1.0)
    assert engine.alive == [0, 2, 3]
    # the tracker was rebuilt in push_sum mode at the new size
    assert engine._tracker.mode == "push_sum" and engine._tracker.m == 3
    # continue through the rejoin via the normal loop
    for epoch in range(3, 6):
        state, rec = engine.run_epoch(surgically if epoch == 3 else state,
                                      epoch, task["batch_fn"])
    assert engine.alive == [0, 2, 3, 1]
    assert state.psum_weight.shape == (4,)
    assert (np.asarray(state.psum_weight) > 0).all()
    np.testing.assert_allclose(np.asarray(state.psum_weight).sum(), 4.0,
                               rtol=1e-5)
