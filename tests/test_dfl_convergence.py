"""The paper's claims, numerically: Sec. IV repro, Theorem 1, Lemmas 1/3.

The regression task is exactly Sec. IV: M=5 servers x N=5 clients, D=100
points/client, w* = (5, 2).  The loss is 0.5*MSE (mu-strongly convex,
L-smooth with known constants), so every theory quantity is computable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, FLTopology, build_dfl_epoch_step,
                        build_fedavg_epoch_step, build_local_only_epoch_step,
                        init_dfl_state)
from repro.data import RegressionSpec, make_regression_data
from repro.optim import sgd


def _setup(m=5, n=5, t_c=50, t_s=25, seed=0, heterogeneity=0.0,
           graph="ring"):
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind=graph)
    spec = RegressionSpec(heterogeneity=heterogeneity)
    data = make_regression_data(topo, spec, seed=seed)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    def loss_fn(w, batch, rng):
        xx, yy = batch
        return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

    # full-batch gradient each local iteration (the paper's Eq. 3 setting)
    bx = jnp.broadcast_to(x, (t_c,) + x.shape)
    by = jnp.broadcast_to(y, (t_c,) + y.shape)
    # optimal w*: global least squares over all 2500 points
    xf = np.asarray(x).reshape(-1, x.shape[-1])
    yf = np.asarray(y).reshape(-1)
    w_star = np.linalg.lstsq(xf, yf, rcond=None)[0]
    # smoothness constants of the per-client quadratic risks
    lmax = max(float(np.linalg.eigvalsh(
        np.asarray(x)[i, j].T @ np.asarray(x)[i, j] / x.shape[2]).max())
        for i in range(m) for j in range(n))
    mumin = min(float(np.linalg.eigvalsh(
        np.asarray(x)[i, j].T @ np.asarray(x)[i, j] / x.shape[2]).min())
        for i in range(m) for j in range(n))
    return topo, loss_fn, (bx, by), w_star, mumin, lmax


def _run(topo, loss_fn, batches, gamma, epochs, mode="gossip", w0=None):
    cfg = DFLConfig(topology=topo, consensus_mode=mode)
    opt = sgd(gamma)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, w0 if w0 is not None else jnp.zeros((2,)),
                           opt, jax.random.key(0))
    metrics = None
    for _ in range(epochs):
        state, metrics = step(state, batches)
    return state, metrics


def test_paper_sec4_reproduction():
    """5x5, w*=(5,2): servers reach consensus and land near w*."""
    topo, loss_fn, batches, w_star, mu, lsm = _setup(t_c=50, t_s=25)
    gamma = 0.4 / (lsm * topo.t_client)          # < 1/(L T_C) (Thm 1)
    state, metrics = _run(topo, loss_fn, batches, gamma, epochs=60)
    servers = state.client_params[:, 0]           # (M, 2), post-broadcast
    # (a) consensus: max pairwise distance between server models is tiny
    pair = jnp.linalg.norm(servers[:, None] - servers[None], axis=-1)
    assert float(pair.max()) < 1e-3
    # (b) accuracy: all servers within the Thm-1 epsilon of w*
    eps = topo.epsilon_bound(gamma, mu, lsm, theta=60.0)
    err = float(jnp.linalg.norm(servers - jnp.asarray(w_star), axis=-1).max())
    assert err < max(eps, 0.05), (err, eps)
    # near-perfect fit in absolute terms too
    assert err < 0.2


def test_lemma1_disagreement_bound():
    """||w_p^i - wbar_p|| <= sigma^p ||W_0 - 1 wbar_0|| + sqrt(M) T_C th g s/(1-s)."""
    topo, loss_fn, batches, w_star, mu, lsm = _setup(t_c=20, t_s=5,
                                                     heterogeneity=1.0)
    gamma = 0.4 / (lsm * topo.t_client)
    theta = 80.0  # loose gradient bound for this data (checked below)
    cfg = DFLConfig(topology=topo)
    opt = sgd(gamma)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    s = topo.sigma()
    bound_tail = np.sqrt(topo.num_servers) * topo.t_client * theta * gamma \
        * s / (1 - s)
    for p in range(1, 8):
        state, metrics = step(state, batches)
        servers = state.client_params[:, 0]
        wbar = servers.mean(0)
        lhs = float(jnp.linalg.norm(servers - wbar, axis=-1).max())
        # W_0 identical across servers => sigma^p term vanishes
        assert lhs <= bound_tail + 1e-6, (p, lhs, bound_tail)


def test_lemma3_client_drift_bound():
    """||w_s^{ij} - w_p^i|| <= gamma T_C theta within every epoch."""
    topo, loss_fn, batches, *_ , lsm = _setup(t_c=30, t_s=10)
    gamma = 0.2 / (lsm * topo.t_client)
    cfg = DFLConfig(topology=topo)
    opt = sgd(gamma)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    theta = 80.0
    for _ in range(5):
        state, metrics = step(state, batches)
        assert float(metrics.client_drift) <= gamma * topo.t_client * theta


def test_fedavg_baseline_beats_dfl_slightly():
    """exact_mean (hierarchical/FedAvg idealization, sigma=0) must end at
    least as close to w* as ring-gossip DFL — Thm 1's epsilon is monotone in
    sigma_A."""
    topo, loss_fn, batches, w_star, mu, lsm = _setup(t_c=25, t_s=2,
                                                     heterogeneity=1.5)
    gamma = 0.3 / (lsm * topo.t_client)
    s_dfl, _ = _run(topo, loss_fn, batches, gamma, 40, mode="gossip")
    s_fed, _ = _run(topo, loss_fn, batches, gamma, 40, mode="exact_mean")
    err = lambda st: float(jnp.linalg.norm(
        st.client_params[:, 0] - jnp.asarray(w_star), axis=-1).max())
    assert err(s_fed) <= err(s_dfl) + 1e-3


def test_local_only_ablation_disagrees():
    """No consensus + heterogeneous clients -> servers drift apart."""
    topo, loss_fn, batches, *_ , lsm = _setup(t_c=25, t_s=2,
                                              heterogeneity=2.0)
    gamma = 0.3 / (lsm * topo.t_client)
    s_loc, m_loc = _run(topo, loss_fn, batches, gamma, 40, mode="none")
    s_dfl, m_dfl = _run(topo, loss_fn, batches, gamma, 40, mode="gossip")
    assert float(m_loc.server_disagreement) > 10 * float(
        m_dfl.server_disagreement)


@pytest.mark.parametrize("mode", ["collapsed", "chebyshev"])
def test_beyond_paper_consensus_modes_converge(mode):
    topo, loss_fn, batches, w_star, mu, lsm = _setup(t_c=25, t_s=25)
    gamma = 0.4 / (lsm * topo.t_client)
    state, metrics = _run(topo, loss_fn, batches, gamma, 150, mode=mode)
    servers = state.client_params[:, 0]
    err = float(jnp.linalg.norm(servers - jnp.asarray(w_star), axis=-1).max())
    assert err < 0.2, err
    assert float(metrics.server_disagreement) < 1e-2


def test_collapsed_bitwise_matches_gossip():
    """collapsed is the same operator as T_S gossip rounds (within fp32)."""
    topo, loss_fn, batches, *_ = _setup(t_c=10, t_s=8)
    g = 1e-4
    s1, m1 = _run(topo, loss_fn, batches, g, 3, mode="gossip")
    s2, m2 = _run(topo, loss_fn, batches, g, 3, mode="collapsed")
    np.testing.assert_allclose(np.asarray(s1.client_params),
                               np.asarray(s2.client_params),
                               rtol=5e-5, atol=5e-6)


def test_fault_tolerance_drop_server():
    """Graph surgery mid-training: drop a server, keep converging."""
    topo, loss_fn, batches, w_star, mu, lsm = _setup(m=5, t_c=20, t_s=10)
    gamma = 0.3 / (lsm * topo.t_client)
    state, _ = _run(topo, loss_fn, batches, gamma, 10)
    new_topo, keep = topo.drop_server(2)
    # re-shard: drop the failed server's row everywhere
    new_params = jax.tree.map(lambda l: l[np.asarray(keep)],
                              state.client_params)
    cfg2 = DFLConfig(topology=new_topo)
    opt = sgd(gamma)
    step2 = jax.jit(build_dfl_epoch_step(cfg2, loss_fn, opt))
    state2 = init_dfl_state(cfg2, jnp.zeros((2,)), opt, jax.random.key(1))
    state2 = state2._replace(client_params=new_params)
    nb = jax.tree.map(lambda b: b[:, np.asarray(keep)], batches)
    for _ in range(80):
        state2, m2 = step2(state2, nb)
    servers = state2.client_params[:, 0]
    # the survivors' optimum (dropping a server drops its clients' data)
    xs = np.asarray(nb[0][0]).reshape(-1, 2)
    ys = np.asarray(nb[1][0]).reshape(-1)
    w_star2 = np.linalg.lstsq(xs, ys, rcond=None)[0]
    err = float(jnp.linalg.norm(servers - jnp.asarray(w_star2), axis=-1).max())
    assert err < 0.25, err
    assert float(m2.server_disagreement) < 1e-2
