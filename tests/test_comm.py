"""The compressed-gossip communication subsystem (repro.comm): compressor
round-trip properties, metadata-vs-analytic byte cross-checks, error
feedback, the CompressedBackend wrapper over every consensus backend, the
DFL epoch-step integration (exact degeneration when compression is off,
EF residual threading, surgery reset), and the engine's wire accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import accounting as acc
from repro.comm import compressors as cp
from repro.comm import error_feedback as ef
from repro.core import (DFLConfig, EpochSchedule, FaultEvent, FaultSchedule,
                        FLTopology, TopologySchedule, build_dfl_epoch_step,
                        init_dfl_state, make_engine)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd

M, T_S = 5, 7


def _rows(key, d=100, m=M, scale=3.0):
    return jax.random.normal(key, (m, d)) * scale


# ---------------------------------------------------------------------------
# compressors: round-trip properties
# ---------------------------------------------------------------------------


def test_identity_roundtrip_exact(rng_key):
    x = _rows(rng_key)
    np.testing.assert_array_equal(
        np.asarray(cp.IdentityCompressor().roundtrip(x)), np.asarray(x))


@pytest.mark.parametrize("spec", ["int8", "int4", "int8:32", "int4:16"])
def test_quantizer_error_bounded_by_chunk_scale(spec, rng_key):
    """|x - D(C(x))| <= one quantization step of the element's chunk."""
    q = cp.make_compressor(spec)
    x = _rows(rng_key)
    y = q.roundtrip(x, jax.random.fold_in(rng_key, 1))
    step = np.asarray(q._per_elem(q._scales(np.asarray(x)), x.shape[1]))
    assert (np.abs(np.asarray(y - x)) <= step + 1e-6).all()


def test_quantizer_stochastic_rounding_unbiased(rng_key):
    """E[D(C(x))] = x over rounding keys (the EF-friendly property)."""
    q = cp.StochasticQuantizer(bits=8)
    x = _rows(rng_key, d=64)
    ys = jnp.stack([q.roundtrip(x, jax.random.key(i)) for i in range(300)])
    step = float(np.asarray(q._per_elem(q._scales(np.asarray(x)), 64)).max())
    assert float(jnp.abs(ys.mean(0) - x).max()) < 0.2 * step


def test_top_k_keeps_largest(rng_key):
    c = cp.TopKCompressor(ratio=0.1)
    x = _rows(rng_key)
    y = np.asarray(c.roundtrip(x))
    k = c.k_for(x.shape[1])
    for i in range(x.shape[0]):
        kept = np.nonzero(y[i])[0]
        assert len(kept) == k
        thresh = np.sort(np.abs(np.asarray(x[i])))[-k]
        assert (np.abs(np.asarray(x[i])[kept]) >= thresh - 1e-6).all()
        np.testing.assert_allclose(y[i][kept], np.asarray(x[i])[kept])


def test_random_k_shared_coordinates(rng_key):
    """One coordinate set per call, shared by every server (that is what
    makes the indices free on the wire)."""
    c = cp.RandomKCompressor(ratio=0.1)
    x = _rows(rng_key)
    comp = c.compress(x, rng_key)
    assert comp.idx.shape == (c.k_for(x.shape[1]),)
    y = np.asarray(c.decompress(comp, x.shape[1]))
    mask = y != 0
    assert (mask.all(axis=0) | (~mask).any(axis=0)).all()
    with pytest.raises(ValueError, match="shared rng key"):
        c.compress(x)


def test_make_compressor_grammar():
    assert cp.make_compressor("int4:64").chunk == 64
    assert cp.make_compressor("top_k:0.25").ratio == 0.25
    assert isinstance(cp.make_compressor("random_k:0.5"),
                      cp.RandomKCompressor)
    for bad in ("none", "", "zstd", "top_k", "int3"):
        with pytest.raises(ValueError):
            cp.make_compressor(bad)
    with pytest.raises(ValueError, match="ratio"):
        cp.TopKCompressor(ratio=0.0)
    with pytest.raises(ValueError, match="bits"):
        cp.StochasticQuantizer(bits=2)


# ---------------------------------------------------------------------------
# byte accounting: metadata vs analytic cross-check + the tracker
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["identity", "int8", "int4", "int8:32",
                                  "top_k:0.05", "random_k:0.1"])
def test_wire_bytes_metadata_matches_analytic(spec):
    """Compressor.wire_bytes_per_row derives the count from the actual
    compressed payload (eval_shape); accounting.analytic_row_bytes is the
    independent closed form — they must agree everywhere."""
    c = cp.make_compressor(spec)
    for d in (1, 2, 7, 32, 256, 257, 1000, 4096):
        assert c.wire_bytes_per_row(d) == acc.analytic_row_bytes(c, d), \
            (spec, d)


def test_wire_bytes_per_leaf_nd_matches_analytic():
    """Shape-preserving quantizers chunk per leaf ROW (last axis), so the
    ND byte count differs from the flat-row one — both metadata and the
    closed form must agree on that."""
    q = cp.make_compressor("int8:32")
    for shape in ((5, 3, 100), (5, 7, 4, 16), (5, 64), (5, 2, 1)):
        assert q.wire_bytes_per_leaf(shape) == acc.analytic_leaf_bytes(
            q, shape), shape
    # flatten-based compressors reduce to the flat row either way
    t = cp.make_compressor("top_k:0.1")
    assert t.wire_bytes_per_leaf((5, 3, 100)) == acc.analytic_leaf_bytes(
        t, (5, 3, 100)) == acc.analytic_row_bytes(t, 300)


def test_quantizer_nd_roundtrip_matches_per_row(rng_key):
    """The natural-shape (no-flatten) quantizer path: chunking an (M, r, L)
    leaf equals quantizing each (M*r, L) row batch — the layout pjit
    shards locally."""
    q = cp.StochasticQuantizer(bits=8, chunk=16)
    x = jax.random.normal(rng_key, (4, 3, 50)) * 2
    y_nd = q.roundtrip(x)                      # round-to-nearest: no key
    y_2d = q.roundtrip(x.reshape(12, 50)).reshape(4, 3, 50)
    np.testing.assert_array_equal(np.asarray(y_nd), np.asarray(y_2d))


def test_bytes_tracker_counts_live_links():
    c = cp.make_compressor("int8")
    tracker = acc.BytesTracker(c)
    a = tp.metropolis_weights(tp.ring_graph(4))          # 8 directed links
    row = c.wire_bytes_per_row(100)
    got = tracker.update(a, T_S, row_bytes=row, elems_per_row=100)
    assert got == 8 * T_S * row
    assert tracker.per_link.sum() == got
    assert tracker.per_link[0, 2] == 0                   # non-edge: silent
    assert tracker.baseline_bytes == 8 * T_S * 400
    assert tracker.ratio() == pytest.approx(400 / row)
    # push-sum ships one extra f32 weight scalar per message
    ps = acc.BytesTracker(c, push_sum=True)
    got_ps = ps.update(a, T_S, row_bytes=row, elems_per_row=100)
    assert got_ps == 8 * T_S * (row + 4)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


def test_ef_identity_residual_stays_zero(rng_key):
    tree = {"w": _rows(rng_key), "b": _rows(jax.random.fold_in(rng_key, 1),
                                            d=7)}
    res = ef.init_ef_residual(tree)
    msg, new_res = ef.ef_roundtrip(cp.IdentityCompressor(), tree, res,
                                   rng_key)
    for leaf in jax.tree.leaves(new_res):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    np.testing.assert_array_equal(np.asarray(msg["w"]), np.asarray(tree["w"]))


def test_ef_running_mean_of_messages_tracks_signal(rng_key):
    """The EF property: for a CONSTANT signal under a biased compressor
    (top-k), the time-average of the transmitted messages converges to the
    signal — without EF it stays stuck at the top-k support."""
    c = cp.TopKCompressor(ratio=0.2)
    tree = {"w": _rows(rng_key, d=50)}
    res = ef.init_ef_residual(tree)
    total = jnp.zeros_like(tree["w"])
    rounds = 40
    for i in range(rounds):
        msg, res = ef.ef_roundtrip(c, tree, res,
                                   jax.random.fold_in(rng_key, i))
        total = total + msg["w"]
    avg_err = float(jnp.abs(total / rounds - tree["w"]).max())
    no_ef_err = float(jnp.abs(c.roundtrip(tree["w"]) - tree["w"]).max())
    assert avg_err < 0.2 * no_ef_err


# ---------------------------------------------------------------------------
# CompressedBackend: wrapper semantics over every inner backend
# ---------------------------------------------------------------------------


def _tree(m, key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 4, 3)),
            "b": jax.random.normal(k2, (m, 7))}


def test_identity_compressed_backend_is_exact(rng_key):
    """CompressedBackend[identity] == the inner backend, bit for bit, for
    mix and mix_push_sum alike — the wrapper machinery itself is lossless."""
    a_np = tp.metropolis_weights(tp.ring_graph(M))
    tree = _tree(M, rng_key)
    for mode in ("gossip", "gossip_blocked", "collapsed", "chebyshev"):
        inner = cns.make_backend(mode, a_np, T_S)
        wrapped = cns.CompressedBackend(inner, cp.IdentityCompressor(),
                                        error_feedback=True)
        out = wrapped.mix(tree)
        ref = inner.mix(tree)
        for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    a_dir = tp.out_degree_weights(tp.directed_ring(M))
    inner = cns.make_backend("gossip", a_dir, T_S)
    wrapped = cns.CompressedBackend(inner, cp.IdentityCompressor())
    out = wrapped.mix_push_sum(cns.init_push_sum(tree))
    ref = inner.mix_push_sum(cns.init_push_sum(tree))
    np.testing.assert_array_equal(np.asarray(out.weight),
                                  np.asarray(ref.weight))
    for l1, l2 in zip(jax.tree.leaves(out.values),
                      jax.tree.leaves(ref.values)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_compressed_backend_mixes_decompressed_messages(rng_key):
    """mix == inner.mix(roundtrip(tree)) with the same key — the wrapper
    adds nothing beyond the wire simulation."""
    a_np = tp.metropolis_weights(tp.ring_graph(M))
    tree = _tree(M, rng_key)
    q = cp.StochasticQuantizer(bits=8, chunk=8)
    inner = cns.make_backend("gossip", a_np, T_S)
    wrapped = cns.CompressedBackend(inner, q, error_feedback=False)
    key = jax.random.fold_in(rng_key, 3)
    out, res = wrapped.mix_compressed(tree, key=key)
    assert res is None
    ref = inner.mix(cp.roundtrip_tree(q, tree, key))
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-6, atol=1e-6)


def test_compressed_backend_delegates_flags():
    a_np = tp.metropolis_weights(tp.ring_graph(M))
    w = cns.CompressedBackend(cns.make_backend("chebyshev", a_np, T_S),
                              cp.make_compressor("int8"))
    assert w.needs_spectral and not w.supports_directed
    with pytest.raises(ValueError, match="ratio-consensus"):
        w.mix_push_sum(cns.init_push_sum({"w": jnp.ones((M, 2))}))


# ---------------------------------------------------------------------------
# DFL epoch-step integration
# ---------------------------------------------------------------------------


def _setup(m=4, n=2, t_c=3, t_s=6):
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    return topo, task


@pytest.mark.parametrize("dynamic", [False, True])
def test_compression_none_is_bitwise_default_path(dynamic):
    """compression='none' builds NO wrapper: the compiled program, its rng
    stream, and every carried array are bitwise those of the default
    config (the pre-compression path)."""
    topo, task = _setup()
    opt = sgd(1e-3)
    states = {}
    for label, extra in (("default", {}),
                         ("explicit_none", {"compression": "none",
                                            "error_feedback": True})):
        cfg = DFLConfig(topology=topo, dynamic=dynamic, **extra)
        step = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"], opt))
        state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
        assert state.ef_residual is None
        for e in range(2):
            if dynamic:
                sched = EpochSchedule(
                    jnp.ones((topo.num_servers, topo.clients_per_server),
                             jnp.float32),
                    jnp.asarray(topo.mixing_matrix(), jnp.float32))
                state, _ = step(state, task["batches"], sched)
            else:
                state, _ = step(state, task["batches"])
        states[label] = state
    np.testing.assert_array_equal(
        np.asarray(states["default"].client_params),
        np.asarray(states["explicit_none"].client_params))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(states["default"].rng)),
        np.asarray(jax.random.key_data(states["explicit_none"].rng)))


def test_identity_compression_epoch_step_is_exact():
    """The fully-threaded wrapper path (rng split, EF residual carried in
    DFLState) with the identity compressor reproduces the uncompressed
    epoch exactly — the degeneration guarantee at the integration level."""
    topo, task = _setup()
    opt = sgd(1e-3)
    cfg0 = DFLConfig(topology=topo)
    cfg1 = DFLConfig(topology=topo, compression="identity",
                     error_feedback=True)
    step0 = jax.jit(build_dfl_epoch_step(cfg0, task["loss_fn"], opt))
    step1 = jax.jit(build_dfl_epoch_step(cfg1, task["loss_fn"], opt))
    s0 = init_dfl_state(cfg0, jnp.zeros((2,)), opt, jax.random.key(0))
    s1 = init_dfl_state(cfg1, jnp.zeros((2,)), opt, jax.random.key(0))
    assert s1.ef_residual is not None
    for _ in range(2):
        s0, _ = step0(s0, task["batches"])
        s1, _ = step1(s1, task["batches"])
    # identical params (the rng STREAMS differ: the compressed program
    # splits a rounding key — so compare params, not rng)
    np.testing.assert_array_equal(np.asarray(s0.client_params),
                                  np.asarray(s1.client_params))
    for leaf in jax.tree.leaves(s1.ef_residual):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


@pytest.mark.parametrize("mode", ["gossip", "gossip_blocked", "collapsed"])
def test_int8_ef_epoch_step_converges_near_uncompressed(mode):
    """int8 + EF across every traced backend: finite, close to the exact
    path after a few epochs, and the residual is live (non-zero)."""
    topo, task = _setup(t_s=8)
    opt = sgd(1e-3)
    cfg_ref = DFLConfig(topology=topo, consensus_mode=mode)
    cfg_cmp = DFLConfig(topology=topo, consensus_mode=mode,
                        compression="int8:16", error_feedback=True)
    step_ref = jax.jit(build_dfl_epoch_step(cfg_ref, task["loss_fn"], opt))
    step_cmp = jax.jit(build_dfl_epoch_step(cfg_cmp, task["loss_fn"], opt))
    s_ref = init_dfl_state(cfg_ref, jnp.zeros((2,)), opt, jax.random.key(0))
    s_cmp = init_dfl_state(cfg_cmp, jnp.zeros((2,)), opt, jax.random.key(0))
    for _ in range(4):
        s_ref, _ = step_ref(s_ref, task["batches"])
        s_cmp, m_cmp = step_cmp(s_cmp, task["batches"])
    ref = np.asarray(s_ref.client_params)
    out = np.asarray(s_cmp.client_params)
    assert np.isfinite(out).all()
    scale = np.abs(ref).max()
    assert np.abs(out - ref).max() < 0.05 * scale, (mode,
                                                    np.abs(out - ref).max())
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree.leaves(s_cmp.ef_residual))


def test_push_sum_compressed_weight_untouched():
    """Push-sum under compression: the numerator rides the wire simulation,
    the weight recursion is exact — invariants hold and the ratio stays
    finite."""
    topo, task = _setup(t_s=8)
    opt = sgd(1e-3)
    cfg = DFLConfig(topology=topo, mixing="push_sum", compression="int8",
                    error_feedback=True, dynamic=True)
    step = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"], opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    mats = [tp.out_degree_weights(tp.random_direction_drop(
        topo.adjacency(), 0.3, np.random.default_rng(e), ensure_strong=True))
        for e in range(3)]
    mask = jnp.ones((topo.num_servers, topo.clients_per_server), jnp.float32)
    for a_np in mats:
        state, _ = step(state, task["batches"],
                        EpochSchedule(mask, jnp.asarray(a_np, jnp.float32)))
        w = np.asarray(state.psum_weight)
        assert (w > 0).all()
        np.testing.assert_allclose(w.sum(), topo.num_servers, rtol=1e-5)
    assert np.isfinite(np.asarray(state.client_params)).all()


# ---------------------------------------------------------------------------
# engine integration: wire accounting + EF surgery reset
# ---------------------------------------------------------------------------


def test_engine_reports_wire_bytes_and_resets_ef_on_surgery():
    topo, task = _setup()
    engine = make_engine(
        topo, task["loss_fn"], sgd(1e-3), compression="int8",
        error_feedback=True,
        faults=FaultSchedule((FaultEvent(1, "drop", 2),
                              FaultEvent(3, "rejoin", 2))))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    rows = {}
    for epoch in range(4):
        state, rec = engine.run_epoch(state, epoch, task["batch_fn"])
        m_live = engine.topo.num_servers
        assert jax.tree.leaves(state.ef_residual)[0].shape[0] == m_live
        # expected bytes: live directed links x T_S x metadata row bytes
        a = engine.topology_schedule.mixing(engine.topo, epoch)
        links = int(((a != 0) & ~np.eye(m_live, dtype=bool)).sum())
        row = engine._compressor.wire_bytes_per_row(2)
        assert rec["wire_mb"] * 1e6 == links * engine.topo.t_server * row
        assert rec["wire_ratio"] > 1.0
        rows[epoch] = rec["wire_mb"]
    assert rows[1] < rows[0]           # M=3: fewer live links than M=4
    # surgery zeroes the residual (per-server wire debt of a dead topology)
    dirty = state._replace(ef_residual=jax.tree.map(
        lambda x: x + 1.0, state.ef_residual))
    fresh = engine.apply_faults(dirty, 1)
    for leaf in jax.tree.leaves(fresh.ef_residual):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_engine_no_compression_has_no_wire_metrics():
    topo, task = _setup()
    engine = make_engine(topo, task["loss_fn"], sgd(1e-3))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    _, rec = engine.run_epoch(state, 0, task["batch_fn"])
    assert "wire_mb" not in rec and "wire_ratio" not in rec


def test_engine_compressed_shard_map_rejected_with_faults():
    """The mesh-bound flag must survive the compression wrap (the guard
    that keeps shard_map out of fault scenarios sees through it)."""
    topo, task = _setup(m=2)

    class FakeShardMap(cns.ConsensusBackend):
        name = "shard_map"
        mesh_bound = True

        def _mix(self, tree, a):
            return tree

    wrapped = cns.CompressedBackend(
        FakeShardMap(topo.mixing_matrix(), topo.t_server),
        cp.make_compressor("int8"))
    assert wrapped.mesh_bound
    with pytest.raises(ValueError, match="mesh-bound"):
        make_engine(topo, task["loss_fn"], sgd(1e-3),
                    consensus_backend=wrapped,
                    faults=FaultSchedule((FaultEvent(1, "drop", 1),)))


# ---------------------------------------------------------------------------
# CLI / config plumbing
# ---------------------------------------------------------------------------


def test_trainer_cli_compression_flags():
    from repro.launch.train import build_parser
    args = build_parser().parse_args(
        ["--compression", "top_k:0.05", "--error-feedback"])
    assert args.compression == "top_k:0.05" and args.error_feedback
    args = build_parser().parse_args([])
    assert args.compression == "none" and not args.error_feedback


def test_plan_compression_defaults():
    from repro.launch.plans import plan_for
    assert plan_for("mixtral_8x22b").compression == "int8"
    assert plan_for("mixtral_8x22b").error_feedback
    assert plan_for("smollm_360m").compression == "none"


def test_active_compressor_resolution():
    from repro.core.dfl import active_compressor, wants_error_feedback
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=2,
                      t_server=2)
    cfg = DFLConfig(topology=topo)
    assert active_compressor(cfg) is None and not wants_error_feedback(cfg)
    cfg = DFLConfig(topology=topo, compression="int4", error_feedback=True)
    assert active_compressor(cfg).bits == 4 and wants_error_feedback(cfg)
    # injected compressed backend wins over the (unset) config string
    backend = cns.make_backend("gossip", topo.mixing_matrix(), 2,
                               compression="top_k:0.1", error_feedback=True)
    cfg = DFLConfig(topology=topo, consensus_backend=backend)
    assert isinstance(active_compressor(cfg), cp.TopKCompressor)
    assert wants_error_feedback(cfg)
    # an injected UNcompressed backend: config string does not re-wrap
    plain = cns.make_backend("gossip", topo.mixing_matrix(), 2)
    cfg = dataclasses.replace(cfg, consensus_backend=plain,
                              compression="int8")
    assert active_compressor(cfg) is None
