"""Docs cannot rot: every backticked ``module.function`` reference in
README.md and docs/*.md must resolve against the live package.

Checked tokens are backtick spans that are pure dotted identifiers whose
first segment is either ``repro``/a ``repro`` subpackage (``core.dfl.x``
styles get the ``repro.`` prefix) or a capitalised name exported from
``repro.core`` (``FLTopology.drop_server``).  File paths (slashes), CLI
snippets (spaces/dashes), and foreign names (``np.linalg``) never match,
so prose stays free-form.
"""
import dataclasses
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))

TOKEN = re.compile(r"`([A-Za-z_]\w*(?:\.[A-Za-z_]\w*)+)`")
PACKAGES = {"repro", "core", "kernels", "launch", "models", "configs",
            "data", "checkpoint", "optim", "comm", "analysis", "obs"}


def _has_attr(obj, attr: str) -> bool:
    """getattr that also accepts dataclass fields without defaults (they
    are not class attributes) and NamedTuple fields."""
    if hasattr(obj, attr):
        return True
    if isinstance(obj, type):
        if dataclasses.is_dataclass(obj) and attr in {
                f.name for f in dataclasses.fields(obj)}:
            return True
        if attr in getattr(obj, "_fields", ()):
            return True
    return False


def _resolve(token: str) -> bool:
    first = token.split(".", 1)[0]
    if first in PACKAGES:
        parts = token.split(".")
        if parts[0] != "repro":
            parts = ["repro"] + parts
        for k in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:k]))
            except ImportError:
                continue
            for attr in parts[k:]:
                if not _has_attr(obj, attr):
                    return False
                obj = getattr(obj, attr, obj)
            return True
        return False
    if first[0].isupper():
        # class exported from the core namespace, e.g. FLTopology.sigma
        core = importlib.import_module("repro.core")
        obj = getattr(core, first, None)
        if obj is None:
            return False
        for attr in token.split(".")[1:]:
            if not _has_attr(obj, attr):
                return False
            obj = getattr(obj, attr, obj)
        return True
    return True  # foreign prefix: not ours to check


def _checkable(token: str) -> bool:
    first = token.split(".", 1)[0]
    return first in PACKAGES or (first[0].isupper()
                                 and hasattr(importlib.import_module(
                                     "repro.core"), first))


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_refs_resolve(path):
    assert path.exists(), f"{path} is missing"
    tokens = sorted(set(TOKEN.findall(path.read_text())))
    checked = [t for t in tokens if _checkable(t)]
    bad = [t for t in checked if not _resolve(t)]
    assert not bad, (f"{path.name}: unresolvable code references {bad} — "
                     f"the paper map / docs drifted from the package")


def test_docs_exist_and_are_checked():
    """The documentation layer this repo promises: README + the two docs,
    each containing a meaningful number of live code references."""
    counts = {}
    for path in DOC_FILES:
        tokens = set(TOKEN.findall(path.read_text()))
        counts[path.name] = sum(1 for t in tokens if _checkable(t))
    assert {"README.md", "paper_map.md", "dynamic_federation.md",
            "static_analysis.md", "observability.md"} <= set(counts), counts
    assert counts["paper_map.md"] >= 20, counts
    assert counts["dynamic_federation.md"] >= 10, counts
    assert counts["static_analysis.md"] >= 12, counts
    assert counts["observability.md"] >= 12, counts
    assert counts["README.md"] >= 5, counts
