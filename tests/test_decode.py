"""Serving correctness: prefill + decode == full forward, per architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import arch_params
from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as tf

OPTS = tf.ApplyOptions(remat=False, moe_no_drop=True)

ARCH_PARAMS = arch_params(ARCH_IDS)


def _batch(cfg, key, b, s):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.frontend is not None:
        name = ("patch_embeds" if cfg.frontend.kind == "vision_patches"
                else "frames")
        n = cfg.frontend.num_tokens or s
        batch[name] = jax.random.normal(jax.random.fold_in(key, 3),
                                        (b, n, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_decode_matches_forward(arch_id, rng_key):
    """Greedy-decode 3 tokens; logits at each step must match running the
    full forward over the extended sequence (drop-free MoE)."""
    cfg = get_smoke(arch_id)
    params = tf.init_params(rng_key, cfg)
    b, s = 2, 24
    batch = _batch(cfg, rng_key, b, s)
    # vlm prefill consumes patch positions too
    n_extra = (cfg.frontend.num_tokens
               if cfg.frontend and cfg.frontend.kind == "vision_patches"
               else 0)
    logits, cache = jax.jit(
        lambda p, bt: tf.prefill(p, cfg, bt, max_len=s + n_extra + 4,
                                 cache_dtype=jnp.float32, opts=OPTS)
    )(params, batch)
    dec = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    fwd = jax.jit(lambda p, bt: tf.forward(p, cfg, bt, opts=OPTS))

    toks = batch["tokens"]
    for step in range(3):
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        nxt = nxt.astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
        logits, cache = dec(params, nxt, cache)
        ref_batch = dict(batch)
        ref_batch["tokens"] = toks
        full, _ = fwd(params, ref_batch)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0, :cfg.vocab_size], jnp.float32),
            np.asarray(full[:, -1, :cfg.vocab_size], jnp.float32),
            rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache(rng_key):
    """mixtral smoke (SWA all layers): cache shorter than the sequence —
    decode must agree with full forward once the window has wrapped."""
    cfg = get_smoke("mixtral_8x22b")      # window = 32 in smoke
    params = tf.init_params(rng_key, cfg)
    b, s = 1, 40                          # s > window: ring has wrapped
    batch = _batch(cfg, rng_key, b, s)
    logits, cache = jax.jit(
        lambda p, bt: tf.prefill(p, cfg, bt, max_len=64,
                                 cache_dtype=jnp.float32, opts=OPTS)
    )(params, batch)
    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    logits2, _ = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))(
        params, nxt, cache)
    full, _ = tf.forward(
        params, cfg,
        {**batch, "tokens": jnp.concatenate([batch["tokens"], nxt], 1)},
        opts=OPTS)
    np.testing.assert_allclose(
        np.asarray(logits2[:, 0, :cfg.vocab_size]),
        np.asarray(full[:, -1, :cfg.vocab_size]), rtol=2e-3, atol=2e-3)


def test_serve_driver_runs(rng_key):
    from repro.launch.serve import serve
    res = serve("qwen3-1.7b", batch=2, prompt_len=16, gen=4)
    assert res["generated"].shape == (2, 4)
    assert res["tok_per_s"] > 0


def test_mla_absorbed_decode_matches(rng_key):
    """Beyond-paper MLA absorbed-decode == naive latent expansion."""
    from repro.models import modules as nn
    cfg = get_smoke("deepseek_v2_236b")
    p = nn.mla_init(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 1, cfg.d_model)) * 0.3
    cache1 = nn.mla_cache_init(cfg, 2, 8, jnp.float32)
    cache2 = nn.mla_cache_init(cfg, 2, 8, jnp.float32)
    pos = jnp.zeros((), jnp.int32)
    y1, _ = nn.mla_decode_step(p, x, cache1, pos, cfg, absorbed=False)
    y2, _ = nn.mla_decode_step(p, x, cache2, pos, cfg, absorbed=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
