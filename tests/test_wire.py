"""Physical-wire quantized gossip: the int8/int4 codes that actually cross
the collectives.  Covers the shard-shaped codec (encode_block/decode_block
== the compressor round-trip, bit for bit), the shared dither convention,
the in-graph wire reference vs the blocked streaming schedule (bitwise),
the CompressedBackend wire='physical' dispatch + error feedback, pad-tail
neutrality, the counter-based O(k) random-k sampler, the fused
gather-dequant-mix-requant kernels (per-leaf and bucketed), the BUCKETED
wire layout (one padded code buffer + one scale buffer for the whole
pytree -> one all-gather pair per round), the engine's physical byte
ledger, and — in subprocesses with a forced multi-device mesh — the
shard_map / ring collective programs: physical vs simulated bitwise
parity, the compiled-HLO proof that the all-gather / ppermute operands
are s8 codes + f32 scales (not bf16/f32 payload), and the
one-collective-pair-per-round site count invariant in the leaf count."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import accounting as acc
from repro.comm import compressors as cp
from repro.core import (DFLConfig, EpochSchedule, FLTopology,
                        build_dfl_epoch_step, init_dfl_state, make_engine)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd

M, T_S = 5, 7


def _ring(m=M):
    return jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)


def _tree(key, m=M):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 4, 33)) * 2,
            "b": jax.random.normal(k2, (m, 7))}


# ---------------------------------------------------------------------------
# the codec: one numerics definition, packed int4, shared dither
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("length", [1, 2, 7, 16, 255])
def test_pack_unpack_int4_roundtrip(length):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-8, 8, (3, length)), jnp.int8)
    packed = cp.pack_int4(codes)
    assert packed.shape[-1] == -(-length // 2)          # two codes per byte
    np.testing.assert_array_equal(
        np.asarray(cp.unpack_int4(packed, length)), np.asarray(codes))


@pytest.mark.parametrize("spec", ["int8:16", "int4:16", "int8", "int4:8"])
def test_encode_block_is_the_compressor_roundtrip(spec, rng_key):
    """decode_block(encode_block(x)) is BITWISE decompress(compress(x))
    under the same dither — the wire format and the in-graph simulation
    share one numerics definition."""
    q = cp.make_compressor(spec)
    x = jax.random.normal(rng_key, (M, 100)) * 3
    u = cp.wire_dither(jax.random.key(0), x.shape, leaf=0, rnd=2, server=1,
                       block=3)
    codes, scales = q.encode_block(x, u)
    ref = q.decompress(q.compress(x, dither=u), x.shape[-1])
    np.testing.assert_array_equal(
        np.asarray(q.decode_block(codes, scales, x.shape[-1])),
        np.asarray(ref))
    code_bytes, scale_bytes = q.wire_block_bytes(100)
    assert codes.shape[-1] == code_bytes         # int8: 1 B/code; int4: 2/B
    assert scales.shape[-1] * 4 == scale_bytes


def test_wire_dither_convention_is_coordinate_keyed():
    key = jax.random.key(3)
    base = cp.wire_dither(key, (8,), leaf=0, rnd=1, server=2, block=3)
    again = cp.wire_dither(key, (8,), leaf=0, rnd=1, server=2, block=3)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(again))
    for other in ({"leaf": 1, "rnd": 1, "server": 2, "block": 3},
                  {"leaf": 0, "rnd": 2, "server": 2, "block": 3},
                  {"leaf": 0, "rnd": 1, "server": 3, "block": 3},
                  {"leaf": 0, "rnd": 1, "server": 2, "block": 4}):
        assert not np.array_equal(
            np.asarray(cp.wire_dither(key, (8,), **other)), np.asarray(base))
    u = np.asarray(base)
    assert (u >= 0).all() and (u < 1).all()      # floor(0 + u) == 0 for pads


# ---------------------------------------------------------------------------
# counter-based random-k sampling (O(k) at LM scale)
# ---------------------------------------------------------------------------


def test_keyed_index_sample_distinct_uniform_coordinated():
    for d, k in ((10, 10), (1000, 37), (257, 1), (2, 2)):
        idx = np.asarray(cp.keyed_index_sample(jax.random.key(3), d, k))
        assert len(set(idx.tolist())) == k                    # a bijection
        assert idx.min() >= 0 and idx.max() < d
    # seed coordination: the property that makes random-k index-free on
    # the wire — every server regenerates the identical coordinate set
    a = cp.keyed_index_sample(jax.random.key(5), 100, 10)
    b = cp.keyed_index_sample(jax.random.key(5), 100, 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="0 < k <= d"):
        cp.keyed_index_sample(jax.random.key(0), 4, 5)
    # 32-bit ceiling: past int32 the gather indices would silently alias
    with pytest.raises(ValueError, match="32-bit"):
        cp.keyed_index_sample(jax.random.key(0), 1 << 31, 8)


def test_keyed_index_sample_lm_scale_is_o_k():
    """d = 2^30: the old jax.random.permutation sampler would allocate and
    sort a 4 GB index vector; the counter hash touches k counters."""
    idx = np.asarray(jax.jit(
        lambda key: cp.keyed_index_sample(key, 1 << 30, 8))(
            jax.random.key(1)))
    assert len(set(idx.tolist())) == 8
    assert idx.min() >= 0 and idx.max() < (1 << 30)


def test_random_k_compressor_uses_counter_sampler(rng_key):
    c = cp.RandomKCompressor(ratio=0.1)
    x = jax.random.normal(rng_key, (4, 50))
    comp = c.compress(x, rng_key)
    np.testing.assert_array_equal(
        np.asarray(comp.idx),
        np.asarray(cp.keyed_index_sample(rng_key, 50, 5)))


# ---------------------------------------------------------------------------
# in-graph wire gossip: schedules agree bitwise; pads are inert
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["int8:16", "int4:16"])
@pytest.mark.parametrize("transpose", [False, True],
                         ids=["symmetric", "push_sum_operator"])
def test_wire_round_major_equals_block_major_bitwise(spec, transpose,
                                                     rng_key):
    """The einsum-style (round-major) and blocked-streaming (block-major)
    wire schedules are the identical operator bit for bit — blocks gossip
    and encode independently."""
    a = _ring()
    a = jnp.swapaxes(a, 0, 1) if transpose else a
    codec = cp.make_compressor(spec)
    tree = _tree(rng_key)
    key = jax.random.key(11)
    o1 = jax.jit(lambda t: cns.gossip_scan_wire(
        a, t, T_S, codec, key, block=32))(tree)
    o2 = jax.jit(lambda t: cns.gossip_scan_wire(
        a, t, T_S, codec, key, block=32, block_major=True))(tree)
    for l1, l2 in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_wire_gossip_zero_pad_tail_is_inert(rng_key):
    """The ragged tail block is zero-padded; zeros never perturb a real
    chunk's absmax scale and quantize to zero codes, so the ragged run is
    bitwise the explicitly-padded run and pads stay exactly zero."""
    a = _ring()
    codec = cp.StochasticQuantizer(bits=8, chunk=16)
    key = jax.random.key(2)
    w = jax.random.normal(rng_key, (M, 132)) * 3        # 132 = 4*32 + 4
    ragged = cns.gossip_scan_wire(a, {"w": w}, T_S, codec, key,
                                  block=32)["w"]
    padded = cns.gossip_scan_wire(
        a, {"w": jnp.pad(w, ((0, 0), (0, 28)))}, T_S, codec, key,
        block=32)["w"]
    np.testing.assert_array_equal(np.asarray(ragged),
                                  np.asarray(padded[:, :132]))
    np.testing.assert_array_equal(np.asarray(padded[:, 132:]), 0.0)
    # unit form: a chunk straddling real data and pad keeps the scale of
    # its real elements (|0| never raises an absmax)
    x = jnp.asarray(np.r_[np.full(4, 8.0), np.zeros(12)], jnp.float32)
    _, scales = codec.encode_block(x[None], 0.5)
    assert float(scales[0, 0]) == pytest.approx(8.0 / 127.0)


def test_wire_roundtrip_tree_matches_round0(rng_key):
    """wire_roundtrip_tree IS round 0 of the wire gossip: one round of
    gossip with the identity operator reproduces it exactly."""
    codec = cp.StochasticQuantizer(bits=8, chunk=16)
    tree = _tree(rng_key)
    key = jax.random.key(7)
    ship = cns.wire_roundtrip_tree(codec, tree, key, block=32)
    eye = jnp.eye(M, dtype=jnp.float32)
    one_round = cns.gossip_scan_wire(eye, tree, 1, codec, key, block=32)
    for l1, l2 in zip(jax.tree.leaves(ship), jax.tree.leaves(one_round)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# the bucketed wire: one code buffer for the whole pytree
# ---------------------------------------------------------------------------


def test_bucket_block_layout():
    """blk rounds UP to a multiple of lcm(chunk, 2) so chunks never
    straddle blocks and int4 packs pairwise without a ragged byte."""
    assert cp.bucket_block(139, 1 << 24, 16) == (144, 1)   # pad to unit
    assert cp.bucket_block(139, 32, 16) == (32, 5)         # tile small blk
    assert cp.bucket_block(7, 1 << 24, 3) == (12, 1)       # odd chunk: x2
    assert cp.bucket_block(1, 1, 2) == (2, 1)


def test_bucketed_wire_is_leaf_structure_invariant(rng_key):
    """The bucketed wire flattens the whole pytree into ONE padded code
    buffer, so splitting the same payload across different leaf
    boundaries changes nothing — bitwise.  (The legacy per-leaf layout
    re-padded and re-scaled every leaf.)"""
    a = _ring()
    codec = cp.StochasticQuantizer(bits=8, chunk=16)
    key = jax.random.key(6)
    w = jax.random.normal(rng_key, (M, 132)) * 3
    one = cns.gossip_scan_wire_bucketed(a, {"w": w}, T_S, codec, key,
                                        block=32)
    two = cns.gossip_scan_wire_bucketed(
        a, {"a": w[:, :100], "b": w[:, 100:]}, T_S, codec, key, block=32)
    np.testing.assert_array_equal(
        np.asarray(one["w"]),
        np.asarray(jnp.concatenate([two["a"], two["b"]], axis=1)))


def test_bucketed_roundtrip_tree_matches_round0(rng_key):
    """bucketed_roundtrip_tree IS round 0 of the bucketed wire gossip:
    one identity-operator round reproduces it exactly."""
    codec = cp.StochasticQuantizer(bits=8, chunk=16)
    tree = _tree(rng_key)
    key = jax.random.key(7)
    ship = cns.bucketed_roundtrip_tree(codec, tree, key, block=32)
    eye = jnp.eye(M, dtype=jnp.float32)
    one = cns.gossip_scan_wire_bucketed(eye, tree, 1, codec, key,
                                        block=32)
    for l1, l2 in zip(jax.tree.leaves(ship), jax.tree.leaves(one)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# CompressedBackend wire='physical': dispatch, EF, push-sum, validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["gossip", "gossip_blocked"])
def test_physical_backend_matches_wire_reference(mode, rng_key):
    """Every in-graph mode of CompressedBackend(wire='physical') runs the
    ONE bucketed wire recursion — gossip_scan_wire_bucketed is the oracle
    for both, bit for bit, and the EF residual is what round 0 withheld
    under the same bucket layout."""
    be = cns.make_backend(mode, np.asarray(_ring()), T_S, block=32,
                          compression="int8:16", error_feedback=True,
                          wire="physical")
    assert be.wire == "physical" and be.wire_block == 32
    assert be.name == f"compressed[{mode}+int8+wire]"
    tree = _tree(rng_key)
    key = jax.random.key(4)
    res0 = jax.tree.map(jnp.zeros_like, tree)
    out, res = be.mix_compressed(tree, key=key, residual=res0)
    ref = cns.gossip_scan_wire_bucketed(_ring(), tree, T_S, be.compressor,
                                        key, block=32)
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    # EF: the residual is what round 0 withheld of each server's own model
    ship = cns.bucketed_roundtrip_tree(be.compressor, tree, key, block=32)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(res[k]),
                                      np.asarray(tree[k] - ship[k]))


def test_physical_push_sum_weight_exact(rng_key):
    a_dir = tp.out_degree_weights(tp.directed_ring(M))
    be = cns.make_backend("gossip", a_dir, T_S, block=64,
                          compression="int8:16", wire="physical")
    tree = _tree(rng_key)
    key = jax.random.key(8)
    ps, _ = be.mix_push_sum_compressed(cns.init_push_sum(tree), key=key)
    w = np.asarray(ps.weight)
    assert (w > 0).all()
    np.testing.assert_allclose(w.sum(), M, rtol=1e-5)
    # the numerator rode the quantized bucketed wire, transposed operator
    ref = cns.gossip_scan_wire_bucketed(
        jnp.asarray(a_dir, jnp.float32).T, tree, T_S, be.compressor, key,
        block=64)
    np.testing.assert_array_equal(np.asarray(ps.values["w"]),
                                  np.asarray(ref["w"]))


def test_physical_wire_validation():
    a_np = np.asarray(_ring())
    with pytest.raises(ValueError, match="wire byte format"):
        cns.make_backend("gossip", a_np, T_S, compression="top_k:0.1",
                         wire="physical")
    with pytest.raises(ValueError, match="wire byte format"):
        cns.make_backend("gossip", a_np, T_S, compression="identity",
                         wire="physical")
    for mode in ("collapsed", "chebyshev", "exact_mean"):
        with pytest.raises(ValueError, match="per-round wire"):
            cns.make_backend(mode, a_np, T_S, compression="int8",
                             wire="physical")
    with pytest.raises(ValueError, match="simulated.*physical|physical"):
        cns.CompressedBackend(cns.make_backend("gossip", a_np, T_S),
                              cp.make_compressor("int8"), wire="bogus")


def test_active_wire_resolution():
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=2,
                      t_server=2)
    from repro.core.dfl import active_wire
    assert active_wire(DFLConfig(topology=topo)) == \
        ("simulated", cns.DEFAULT_GOSSIP_BLOCK)
    cfg = DFLConfig(topology=topo, compression="int8", wire="physical")
    assert active_wire(cfg)[0] == "physical"
    be = cns.make_backend("gossip_blocked", topo.mixing_matrix(), 2,
                          block=128, compression="int8", wire="physical")
    cfg = DFLConfig(topology=topo, consensus_backend=be)
    assert active_wire(cfg) == ("physical", 128)


# ---------------------------------------------------------------------------
# epoch-step + engine integration
# ---------------------------------------------------------------------------


def _setup(m=4, n=2, t_c=3, t_s=8):
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    return topo, task


def test_physical_epoch_step_converges_near_uncompressed():
    topo, task = _setup()
    opt = sgd(1e-3)
    cfg_ref = DFLConfig(topology=topo)
    cfg_phy = DFLConfig(topology=topo, compression="int8:16",
                        error_feedback=True, wire="physical")
    step_ref = jax.jit(build_dfl_epoch_step(cfg_ref, task["loss_fn"], opt))
    step_phy = jax.jit(build_dfl_epoch_step(cfg_phy, task["loss_fn"], opt))
    s_ref = init_dfl_state(cfg_ref, jnp.zeros((2,)), opt, jax.random.key(0))
    s_phy = init_dfl_state(cfg_phy, jnp.zeros((2,)), opt, jax.random.key(0))
    for _ in range(4):
        s_ref, _ = step_ref(s_ref, task["batches"])
        s_phy, _ = step_phy(s_phy, task["batches"])
    ref = np.asarray(s_ref.client_params)
    out = np.asarray(s_phy.client_params)
    assert np.isfinite(out).all()
    assert np.abs(out - ref).max() < 0.05 * np.abs(ref).max()
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree.leaves(s_phy.ef_residual))


def test_physical_dynamic_push_sum_epoch_step():
    topo, task = _setup()
    opt = sgd(1e-3)
    cfg = DFLConfig(topology=topo, mixing="push_sum", compression="int8:16",
                    error_feedback=True, wire="physical", dynamic=True)
    step = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"], opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    mask = jnp.ones((topo.num_servers, topo.clients_per_server), jnp.float32)
    for e in range(3):
        a_np = tp.out_degree_weights(tp.random_direction_drop(
            topo.adjacency(), 0.3, np.random.default_rng(e),
            ensure_strong=True))
        state, _ = step(state, task["batches"],
                        EpochSchedule(mask, jnp.asarray(a_np, jnp.float32)))
        w = np.asarray(state.psum_weight)
        assert (w > 0).all()
        np.testing.assert_allclose(w.sum(), topo.num_servers, rtol=1e-5)
    assert np.isfinite(np.asarray(state.client_params)).all()


@pytest.mark.parametrize("mixing", ["symmetric", "push_sum"])
def test_engine_physical_ledger_counts_collective_bytes(mixing):
    """Under wire='physical' the BytesTracker charges exactly the bucketed
    codes + scales the collectives gather — the closed form
    accounting.tree_bucketed_wire_bytes_per_server — for BOTH mixing
    modes: push-sum's (M,) weight never crosses a collective (it mixes by
    an in-graph replicated matvec), so no +4 B/msg surcharge may appear
    on the physical ledger (the HLO byte audit counts none)."""
    topo, task = _setup()
    engine = make_engine(topo, task["loss_fn"], sgd(1e-3), mixing=mixing,
                         compression="int8:16", error_feedback=True,
                         wire="physical")
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    _, rec = engine.run_epoch(state, 0, task["batch_fn"])
    q = engine._compressor
    row = acc.tree_bucketed_wire_bytes_per_server(
        q, jnp.zeros((topo.num_servers, 2)), cns.DEFAULT_GOSSIP_BLOCK)
    links = 2 * topo.num_servers                        # directed ring edges
    assert rec["wire_mb"] * 1e6 == links * topo.t_server * row
    # at this toy scale (2 params/server) the 16-element bucket pad
    # dominates, so the ratio is exactly baseline/padded — below 1; real
    # payloads amortise the pad (benchmarks record ~3.9x for int8)
    assert rec["wire_ratio"] == pytest.approx((4 * 2) / row)


def test_engine_zero_gossip_epoch_reports_zero_wire():
    """t_server=0: no gossip rounds, nothing on the wire — the record must
    carry THIS epoch's 0.0 (the update() return), never a stale or
    missing history entry."""
    topo = FLTopology(num_servers=4, clients_per_server=2, t_client=3,
                      t_server=0, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    engine = make_engine(topo, task["loss_fn"], sgd(1e-3),
                         compression="int8:16", wire="physical")
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    for epoch in range(2):
        state, rec = engine.run_epoch(state, epoch, task["batch_fn"])
        assert rec["wire_mb"] == 0.0
        assert np.isfinite(rec["loss"])


def test_push_sum_weight_surcharge_only_on_simulated_wire():
    """BytesTracker: the +4 B/msg push-sum weight rides the SIMULATED wire
    only; the physical ledger is the bare bucketed row on both mixings."""
    q = cp.StochasticQuantizer(bits=8, chunk=16)
    a = np.asarray(_ring())
    row, links = 40, 2 * M
    phys_ps = acc.BytesTracker(q, push_sum=True, wire="physical")
    phys = acc.BytesTracker(q, push_sum=False, wire="physical")
    sim_ps = acc.BytesTracker(q, push_sum=True)
    kw = dict(row_bytes=row, elems_per_row=10)
    assert phys_ps.update(a, T_S, **kw) == phys.update(a, T_S, **kw) \
        == links * T_S * row
    assert sim_ps.update(a, T_S, **kw) == links * T_S * (row + 4)


def test_physical_bytes_closed_form():
    q = cp.StochasticQuantizer(bits=8, chunk=16)
    # d=132, block=32: 5 blocks of (32 codes + 2 scales x 4 B) = 5 x 40
    assert acc.physical_leaf_bytes(q, (M, 132), 32) == 5 * 40
    q4 = cp.StochasticQuantizer(bits=4, chunk=16)
    assert acc.physical_leaf_bytes(q4, (M, 132), 32) == 5 * (16 + 8)
    tree = {"w": jnp.zeros((M, 132)), "b": jnp.zeros((M, 7))}
    assert acc.tree_physical_wire_bytes_per_server(q, tree, 32) == \
        5 * 40 + (7 + 4)
    with pytest.raises(ValueError, match="quantizers"):
        acc.physical_leaf_bytes(cp.TopKCompressor(0.1), (M, 10), 32)


def test_bucketed_bytes_closed_form():
    """tree_bucketed_wire_bytes_per_server: d_tot = 132 + 7 = 139 -> one
    144-element bucket (chunk unit 16): 144 codes + 9 scales; int4 packs
    two codes per byte; a small block tiles instead."""
    q = cp.StochasticQuantizer(bits=8, chunk=16)
    tree = {"w": jnp.zeros((M, 132)), "b": jnp.zeros((M, 7))}
    assert acc.tree_bucketed_wire_bytes_per_server(q, tree, 1 << 24) == \
        144 + 9 * 4
    q4 = cp.StochasticQuantizer(bits=4, chunk=16)
    assert acc.tree_bucketed_wire_bytes_per_server(q4, tree, 1 << 24) == \
        72 + 9 * 4
    assert acc.tree_bucketed_wire_bytes_per_server(q, tree, 32) == \
        5 * (32 + 2 * 4)
    with pytest.raises(ValueError, match="quantizers"):
        acc.tree_bucketed_wire_bytes_per_server(cp.TopKCompressor(0.1),
                                                tree, 32)


def test_trainer_cli_wire_flag():
    from repro.launch.train import build_parser
    args = build_parser().parse_args(["--compression", "int8", "--wire",
                                      "physical"])
    assert args.wire == "physical"
    assert build_parser().parse_args([]).wire == "simulated"


def test_plan_wire_defaults():
    from repro.launch.plans import plan_for
    for arch in ("mixtral_8x22b", "deepseek_v2_236b", "jamba_1_5_large_398b"):
        assert plan_for(arch).wire == "physical", arch
        assert plan_for(arch).compression == "int8"
    assert plan_for("smollm_360m").wire == "simulated"


def test_wire_runner_cache_hits_for_fresh_equal_codec():
    """ShardMapBackend.wire_runner caches per (codec, mode) with
    VALUE-hashed codecs: a freshly constructed StochasticQuantizer of
    equal config must return the SAME runner (a miss would retrace and
    recompile the collective program every epoch); a different config or
    mode must not."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("server",))
    be = cns.ShardMapBackend(mesh, np.eye(1, dtype=np.float32), T_S,
                             {"w": P("server", None)})
    r1 = be.wire_runner(cp.StochasticQuantizer(bits=8, chunk=16))
    assert be.wire_runner(cp.StochasticQuantizer(bits=8, chunk=16)) is r1
    assert be.wire_runner(cp.StochasticQuantizer(bits=4, chunk=16)) \
        is not r1
    assert be.wire_runner(cp.StochasticQuantizer(bits=8, chunk=16),
                          with_shipped=True) is not r1
    assert len(be._wire_runners) == 3


# ---------------------------------------------------------------------------
# the fused gather-dequant-mix-requant kernel (jnp wire path = the oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_gossip_round_kernel_bitwise(bits, rng_key):
    """The fused delta-round kernel reproduces the jnp wire recursion
    (decode -> accumulate reference -> mix -> encode next innovations) bit
    for bit, chained over several rounds."""
    from repro.kernels.consensus_mix import quantized_gossip_round_2d

    m, d, chunk = M, 1024, 32
    q = cp.StochasticQuantizer(bits=bits, chunk=chunk)
    a = _ring()
    x = jax.random.normal(rng_key, (m, d)) * 3
    u0 = jax.random.uniform(jax.random.key(1), (m, d))
    comp = q.compress(x, dither=u0)         # round-0 wire state (R_0 = 0)

    @jax.jit
    def oracle(codes, scales, ref, u):
        ref = ref + q.decompress(cp.Compressed(data=codes, scale=scales), d)
        mixed = cns._wire_mix_rows(a, ref)
        nxt = q.compress(mixed - ref, dither=u)
        return mixed, ref, nxt.data, nxt.scale

    @jax.jit
    def kernel(codes, scales, ref, u):
        return quantized_gossip_round_2d(a, codes, scales, ref, u,
                                         bits=bits, chunk=chunk,
                                         block_d=256)

    codes_r, scales_r = comp.data, comp.scale
    codes_k, scales_k = comp.data, comp.scale
    ref_r = ref_k = jnp.zeros((m, d), jnp.float32)
    for t in range(1, 4):
        u = jax.random.uniform(jax.random.key(10 + t), (m, d))
        w_r, ref_r, codes_r, scales_r = oracle(codes_r, scales_r, ref_r, u)
        w_k, ref_k, codes_k, scales_k = kernel(codes_k, scales_k, ref_k, u)
        np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
        np.testing.assert_array_equal(np.asarray(ref_k), np.asarray(ref_r))
        np.testing.assert_array_equal(np.asarray(codes_k),
                                      np.asarray(codes_r))
        np.testing.assert_array_equal(np.asarray(scales_k),
                                      np.asarray(scales_r))


def test_quantized_gossip_round_kernel_validation(rng_key):
    from repro.kernels.consensus_mix import quantized_gossip_round_2d
    codes = jnp.zeros((M, 100), jnp.int8)
    ref = jnp.zeros((M, 100), jnp.float32)
    with pytest.raises(ValueError, match="divide D"):
        quantized_gossip_round_2d(_ring(), codes, jnp.ones((M, 4)), ref,
                                  jnp.zeros((M, 100)), chunk=32)
    with pytest.raises(ValueError, match="bits"):
        quantized_gossip_round_2d(_ring(), codes, jnp.ones((M, 4)), ref,
                                  jnp.zeros((M, 100)), bits=3, chunk=25)


@pytest.mark.parametrize("bits", [8, 4])
def test_bucketed_round_kernels_bitwise(bits, rng_key):
    """The bucketed-wire kernels — encode (round 0) + the fused
    decode-accumulate-mix-requant round — chained over 3 rounds reproduce
    the jnp bucketed recursion bit for bit: codes, scales, reference band
    and accumulator alike."""
    from repro.kernels.consensus_mix import (bucketed_gossip_round_2d,
                                             quantized_gossip_encode_2d)

    m, d, chunk, block_d = 4, 96, 16, 32
    q = cp.StochasticQuantizer(bits=bits, chunk=chunk)
    # dyadic lazy-ring operator: 0.5 / 0.25 products are exact in f32, so
    # the comparison is FMA-neutral — the pallas kernel and the XLA
    # oracle may fuse the multiply-adds differently, and with exact
    # products both roundings coincide bit for bit
    a_np = np.eye(m, dtype=np.float32) * 0.5
    for i in range(m):
        a_np[i, (i + 1) % m] += 0.25
        a_np[i, (i - 1) % m] += 0.25
    a = jnp.asarray(a_np)
    w0 = jax.random.normal(rng_key, (m, d)) * 3
    u = [jax.random.uniform(jax.random.key(20 + t), (m, d))
         for t in range(4)]

    @jax.jit
    def oracle(w0):
        ref, accum = jnp.zeros((m, d)), jnp.zeros((m, d))
        w, outs = w0, []
        for t in range(3):
            comp = q.compress(w - ref, dither=u[t])
            dec = q.decompress(cp.Compressed(comp.data, comp.scale), d)
            ref = ref + dec
            for j in range(m):
                accum = accum + a[:, j, None] * dec[j]
            w = accum
            outs.append((comp.data, comp.scale, ref, accum))
        return outs

    @jax.jit
    def kernels(w0):
        codes, scales = quantized_gossip_encode_2d(
            w0, jnp.zeros((m, d)), u[0], bits=bits, chunk=chunk,
            block_d=block_d)
        ref, accum, outs = jnp.zeros((m, d)), jnp.zeros((m, d)), []
        for t in range(3):
            accum, ref, nxt_c, nxt_s = bucketed_gossip_round_2d(
                a, codes, scales, ref, accum, u[t + 1], bits=bits,
                chunk=chunk, block_d=block_d)
            outs.append((codes, scales, ref, accum))
            codes, scales = nxt_c, nxt_s
        return outs

    for t, (got, want) in enumerate(zip(kernels(w0), oracle(w0))):
        for name, g, r in zip(("codes", "scales", "ref", "acc"), got,
                              want):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(r),
                err_msg=f"bits={bits} round={t} {name}")


def test_bucketed_kernel_validation():
    from repro.kernels.consensus_mix import (bucketed_gossip_round_2d,
                                             quantized_gossip_encode_2d)
    w = jnp.zeros((M, 100))
    with pytest.raises(ValueError, match="bits"):
        quantized_gossip_encode_2d(w, w, w, bits=3)
    with pytest.raises(ValueError, match="divide D"):
        quantized_gossip_encode_2d(w, w, w, chunk=32)
    codes = jnp.zeros((M, 100), jnp.int8)
    with pytest.raises(ValueError, match="divide D"):
        bucketed_gossip_round_2d(_ring(), codes, jnp.ones((M, 4)), w, w,
                                 w, chunk=32)


# ---------------------------------------------------------------------------
# the collectives themselves: shard_map + ring subprocess parity & HLO
# ---------------------------------------------------------------------------

_SHARD_MAP_WIRE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.comm import compressors as cp
from repro.comm import accounting as acc

m, t_s, blk, chunk = 4, 5, 32, 16
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
tree = {"w": jax.random.normal(jax.random.key(0), (m, 4, 33)) * 2,
        "b": jax.random.normal(jax.random.key(1), (m, 7)),
        "c": jax.random.normal(jax.random.key(2), (m, 11))}
specs = {"w": P("server", None, None), "b": P("server", None),
         "c": P("server", None)}
key = jax.random.key(9)
a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)

# --- bitwise parity: the bucketed collective program == the in-graph
# bucketed reference under shared dither, both operators, int8 AND int4
for bits in (8, 4):
    codec = cp.StochasticQuantizer(bits=bits, chunk=chunk)
    run_p = cns.make_gossip_shard_map(mesh, t_s, specs, block=blk,
                                      codec=codec)
    run_s = cns.make_gossip_shard_map(mesh, t_s, specs, block=blk,
                                      codec=codec, gather_codes=False)
    ref_fn = jax.jit(lambda op, t: cns.gossip_scan_wire_bucketed(
        op, t, t_s, codec, key, block=blk))
    for op in (a, a.T):               # symmetric + push-sum numerator
        out_p, out_s, ref = run_p(op, tree, key), run_s(op, tree, key), \
            ref_fn(op, tree)
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(out_p[k]), np.asarray(ref[k]), err_msg=k)
            np.testing.assert_array_equal(
                np.asarray(out_p[k]), np.asarray(out_s[k]), err_msg=k)
    # with_shipped (the EF hook) is a where-select in the loop carry, not
    # a peeled round: the mixed output stays bitwise the plain program's,
    # and the round-0 transmission is bucketed_roundtrip_tree
    run_ef = cns.make_gossip_shard_map(mesh, t_s, specs, block=blk,
                                       codec=codec, with_shipped=True)
    mixed, shipped = run_ef(a, tree, key)
    plain = run_p(a, tree, key)
    ship_ref = jax.jit(lambda t: cns.bucketed_roundtrip_tree(
        codec, t, key, block=blk))(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(mixed[k]),
                                      np.asarray(plain[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(shipped[k]),
                                      np.asarray(ship_ref[k]), err_msg=k)

# --- compiled HLO: exactly ONE all-gather pair (codes + scales) in the
# round body, invariant in the leaf count — the whole pytree rides one
# bucket, and the gathered bytes ARE the ledger's bucketed closed form
for nleaf in (1, 3, 7):
    t2 = {f"l{i}": jax.random.normal(jax.random.key(i), (m, 13 + 5 * i))
          for i in range(nleaf)}
    s2 = {f"l{i}": P("server", None) for i in range(nleaf)}
    d_tot = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(t2))
    for bits, ws in ((8, False), (4, False), (8, True)):
        q = cp.StochasticQuantizer(bits=bits, chunk=chunk)
        run = cns.make_gossip_shard_map(mesh, t_s, s2, block=blk, codec=q,
                                        with_shipped=ws)
        hlo = jax.jit(run).lower(a, t2, key).compile().as_text()
        cols = acc.hlo_collective_bytes(hlo)
        gathers = [c for c in cols if c["op"] == "all-gather"]
        # 2 SITES in the fori_loop body (executed t_s times each)
        assert len(gathers) == 2, (nleaf, bits, ws, gathers)
        assert sorted(c["dtype"] for c in gathers) == ["f32", "s8"], \
            (nleaf, bits, ws, gathers)
        got = sum(c["bytes"] // m for c in gathers)
        want = acc.tree_bucketed_wire_bytes_per_server(q, t2, blk)
        assert got == want, (nleaf, bits, ws, got, want)
        # nothing payload-sized crosses in float — and int4's s8 buffer
        # is half of int8's via the closed form above
        assert not any(c["dtype"] in ("f32", "bf16", "u16")
                       and c["bytes"] // m >= 4 * d_tot
                       for c in cols), cols

# the uncompressed program really does gather the f32 payload (baseline)
hlo0 = jax.jit(cns.make_gossip_shard_map(mesh, t_s, specs, block=blk)
               ).lower(a, tree).compile().as_text()
base = acc.hlo_collective_bytes(hlo0)
assert any(c["dtype"] == "f32" and c["bytes"] // m == 4 * blk
           for c in base), base
print("OK")
"""


@pytest.mark.slow
def test_shard_map_physical_wire_parity_and_hlo():
    """The tentpole, end to end: the BUCKETED shard_map wire program is
    bitwise the in-graph reference under shared dither (physical ==
    simulated == gossip_scan_wire_bucketed, both operators, int8 AND
    packed int4, with and without the EF hook), and the compiled HLO
    proves each round is exactly one all-gather of s8 codes + one of f32
    scales — regardless of leaf count — whose bytes equal
    accounting.tree_bucketed_wire_bytes_per_server, never a payload-sized
    float buffer."""
    r = subprocess.run([sys.executable, "-c", _SHARD_MAP_WIRE],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]


_RING_WIRE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import consensus as cns
from repro.comm import compressors as cp
from repro.comm import accounting as acc

m, t_s = 4, 6
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
tree = {"w": jax.random.normal(jax.random.key(0), (m, 3, 11)) * 2}
key = jax.random.key(5)
sw, nw = 0.5, 0.25
base = cns.make_ring_gossip(mesh, "server", t_s, sw, nw)(tree)
for bits in (8, 4):
    codec = cp.StochasticQuantizer(bits=bits, chunk=8)
    rp = cns.make_ring_gossip(mesh, "server", t_s, sw, nw, codec=codec)
    rs = cns.make_ring_gossip(mesh, "server", t_s, sw, nw, codec=codec,
                              gather_codes=False)
    op = np.asarray(rp(tree, key)["w"])
    np.testing.assert_array_equal(op, np.asarray(rs(tree, key)["w"]))
    # quantized ring stays near the exact ring (sanity, not parity; int4
    # re-quantizes a ~N(0, 2) payload at every one of the 6 hops)
    tol = 0.1 if bits == 8 else 0.8
    assert np.abs(op - np.asarray(base["w"])).max() < tol, bits
codec = cp.StochasticQuantizer(bits=8, chunk=8)
rp = cns.make_ring_gossip(mesh, "server", t_s, sw, nw, codec=codec)
hlo = jax.jit(rp).lower(tree, key).compile().as_text()
cols = acc.hlo_collective_bytes(hlo)
perms = [c for c in cols if c["op"] == "collective-permute"]
assert sorted({c["dtype"] for c in perms}) == ["f32", "s8"], perms
L = 33                                              # local 3*11 payload
assert all(c["bytes"] == L for c in perms if c["dtype"] == "s8"), perms
assert all(c["bytes"] == 4 * -(-L // 8) for c in perms
           if c["dtype"] == "f32"), perms
print("OK")
"""


@pytest.mark.slow
def test_ring_physical_wire_parity_and_hlo():
    """make_ring_gossip with a codec: ppermute of s8 codes + f32 scales,
    bitwise identical to its simulated (floats-on-the-wire) twin."""
    r = subprocess.run([sys.executable, "-c", _RING_WIRE],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]


_ENGINE_WIRE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import (FLTopology, TopologySchedule, init_dfl_state,
                        make_engine)
from repro.data import RegressionSpec, make_regression_task
from repro.launch import sharding as shd
from repro.optim import sgd

m = 4
topo = FLTopology(num_servers=m, clients_per_server=2, t_client=4,
                  t_server=5, graph_kind="ring")
task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5), seed=0)
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
server_abs = jax.eval_shape(lambda: jnp.zeros((m, 2), jnp.float32))
backend = shd.fl_consensus_backend(topo, mesh, server_abs, tp_axis=None,
                                   block=8, compression="int8:2",
                                   error_feedback=True, wire="physical")
assert backend.wire == "physical" and backend.mesh_bound
# chunk=2 matches d=2: a wider chunk would pad the bucketed code buffer
# past the 8-byte f32 baseline and push the tiny-model ratio below 1
finals = {}
for name, kw in (("einsum_wire", {"compression": "int8:2",
                                  "error_feedback": True,
                                  "wire": "physical"}),
                 ("shard_map_wire", {"consensus_backend": backend})):
    engine = make_engine(
        topo, task["loss_fn"], sgd(1e-3),
        topology_schedule=TopologySchedule(kind="edge_drop", drop_prob=0.4,
                                           seed=3), **kw)
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    state, hist = engine.run(state, 3, task["batch_fn"])
    finals[name] = np.asarray(state.client_params)
    assert hist["wire_ratio"][-1] > 1.0
# same rng stream, same codec numerics -> the einsum wire reference and
# the physical shard_map collectives agree to fp tolerance end to end
# (the wire block differs: 8 vs DEFAULT_GOSSIP_BLOCK covers whole rows
# either way at d=2... keep blocks equal for the strict check)
np.testing.assert_allclose(finals["shard_map_wire"], finals["einsum_wire"],
                           rtol=2e-4, atol=2e-5)
print("OK")
"""


@pytest.mark.slow
def test_engine_shard_map_physical_wire_matches_einsum_wire():
    """Dynamic engine, edge-drop schedule, int8 physical wire: the
    mesh-aware shard_map collective path tracks the in-graph einsum wire
    reference through full epochs."""
    r = subprocess.run([sys.executable, "-c", _ENGINE_WIRE],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]
