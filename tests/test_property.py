"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "package (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import consensus as cns
from repro.core import topology as tp

SETTINGS = dict(max_examples=25, deadline=None)


@given(m=st.integers(2, 12),
       kind=st.sampled_from(["ring", "complete", "star", "line"]),
       mixing=st.sampled_from(["metropolis", "uniform"]))
@settings(**SETTINGS)
def test_mixing_matrices_always_valid(m, kind, mixing):
    adj = tp.build_graph(kind, m)
    a = (tp.metropolis_weights(adj) if mixing == "metropolis"
         else tp.uniform_weights(adj))
    tp.check_mixing_matrix(a, adj)
    # sigma < 1 for every connected graph (Assumption 1 -> contraction)
    assert tp.sigma_a(a, 1) < 1.0


@given(m=st.integers(2, 8), t_s=st.integers(1, 30))
@settings(**SETTINGS)
def test_sigma_monotone_in_t_s(m, t_s):
    a = tp.metropolis_weights(tp.ring_graph(m))
    assert tp.sigma_a(a, t_s + 1) <= tp.sigma_a(a, t_s) + 1e-12


@given(m=st.integers(2, 8), t_s=st.integers(0, 12), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_gossip_preserves_mean_property(m, t_s, seed):
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    w = jax.random.normal(jax.random.key(seed), (m, 13))
    out = cns.gossip_scan(a, {"w": w}, t_s)["w"]
    np.testing.assert_allclose(np.asarray(w.mean(0)),
                               np.asarray(out.mean(0)), rtol=1e-4, atol=1e-4)


@given(m=st.integers(2, 6), t_s=st.integers(1, 10), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_lemma1_contraction_property(m, t_s, seed):
    """||A^t W - 1 wbar|| <= sigma_A(t) ||W - 1 wbar|| for random W."""
    a_np = tp.metropolis_weights(tp.ring_graph(m))
    a = jnp.asarray(a_np, jnp.float32)
    w = jax.random.normal(jax.random.key(seed), (m, 7))
    out = cns.gossip_scan(a, {"w": w}, t_s)["w"]

    def dis(x):
        return float(np.linalg.norm(np.asarray(x - x.mean(0))))

    assert dis(out) <= tp.sigma_a(a_np, t_s) * dis(w) + 1e-5


@given(m=st.integers(2, 6), n=st.integers(1, 4), t_c=st.integers(1, 8),
       t_s=st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_epsilon_bound_positive_and_finite(m, n, t_c, t_s):
    topo = tp.FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                         t_server=t_s)
    gamma = 0.5 * topo.max_step_size(mu=1.0, lsmooth=4.0)
    eps = topo.epsilon_bound(gamma, 1.0, 4.0, theta=10.0)
    assert np.isfinite(eps) and eps > 0


@given(seed=st.integers(0, 9999), d=st.integers(1, 5000),
       ratio=st.floats(0.01, 1.0))
@settings(**SETTINGS)
def test_keyed_index_sample_is_a_permutation_prefix(seed, d, ratio):
    """The counter-based random-k sampler (comm.compressors.
    keyed_index_sample): k DISTINCT in-range indices for every (key, d, k),
    and identical on regeneration — the properties that let receivers
    rebuild the coordinate set from the shared seed with zero index bytes."""
    from repro.comm.compressors import keyed_index_sample
    k = max(1, min(d, int(round(ratio * d))))
    key = jax.random.key(seed)
    idx = np.asarray(keyed_index_sample(key, d, k))
    assert idx.shape == (k,) and idx.dtype == np.int32
    assert idx.min() >= 0 and idx.max() < d
    assert len(np.unique(idx)) == k                       # a bijection
    np.testing.assert_array_equal(
        idx, np.asarray(keyed_index_sample(key, d, k)))   # seed-coordinated


def test_keyed_index_sample_marginal_uniformity():
    """Per-coordinate selection frequency over many keys is near-uniform:
    the Feistel counter hash must not favour any index.  400 keys x k=8 of
    d=32 -> expected 100 hits per coordinate; a chi-square statistic under
    ~3x the dof rules out gross bias without being flaky."""
    from repro.comm.compressors import keyed_index_sample
    d, k, n_keys = 32, 8, 400
    counts = np.zeros(d)
    sample = jax.jit(lambda key: keyed_index_sample(key, d, k))
    for s in range(n_keys):
        counts[np.asarray(sample(jax.random.key(s)))] += 1
    expected = n_keys * k / d
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < 3 * d, (chi2, counts)


@given(seed=st.integers(0, 999), rows=st.integers(1, 64),
       d=st.sampled_from([8, 64, 128]))
@settings(**SETTINGS)
def test_rmsnorm_kernel_property(seed, rows, d):
    from repro.kernels import ops
    from repro.kernels.ref import rmsnorm_ref
    x = jax.random.normal(jax.random.key(seed), (rows, d))
    s = jax.random.normal(jax.random.fold_in(jax.random.key(seed), 1), (d,))
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, s, block_rows=16)),
                               np.asarray(rmsnorm_ref(x, s)),
                               rtol=3e-5, atol=3e-5)


@given(seed=st.integers(0, 99), sq=st.sampled_from([32, 64, 96]),
       extra=st.integers(0, 70),
       h=st.sampled_from([1, 2, 4]), group=st.sampled_from([1, 2]),
       causal=st.booleans())
@settings(max_examples=20, deadline=None)
def test_flash_attention_property(seed, sq, extra, h, group, causal):
    from repro.kernels import ops
    from repro.kernels.ref import attention_ref
    sk = sq + extra
    kvh = h
    hq = h * group
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, hq, 32))
    k = jax.random.normal(ks[1], (1, sk, kvh, 32))
    v = jax.random.normal(ks[2], (1, sk, kvh, 32))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# robust (Byzantine-screening) gossip invariants
# ---------------------------------------------------------------------------


def _distinct_int_tree(seed, m, d):
    """(m, d) float32 leaf of DISTINCT small integers: integer-valued f32
    sums are exact and ties are impossible, so rank screens are testable
    bitwise."""
    rng = np.random.default_rng(seed)
    vals = rng.choice(4096, size=m * d, replace=False).astype(np.float32)
    vals -= 2048.0
    return jnp.asarray(vals.reshape(m, d))


@given(seed=st.integers(0, 99), m=st.integers(3, 8), d=st.integers(1, 6),
       f=st.integers(0, 1),
       kind=st.sampled_from(["ring", "complete", "star"]),
       perm_seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_robust_screens_are_permutation_equivariant(seed, m, d, f, kind,
                                                    perm_seed):
    """Relabelling the servers commutes with the screen: mixing the
    permuted state on the conjugated matrix equals permuting the mixed
    output — for the trimmed mean (when the graph is inside its breakdown
    point) and the median, bitwise."""
    adj = tp.build_graph(kind, m)
    a = jnp.asarray(tp.metropolis_weights(adj), jnp.float32)
    w = _distinct_int_tree(seed, m, d)
    perm = np.random.default_rng(perm_seed).permutation(m)
    pa = a[jnp.ix_(perm, perm)]
    pw = w[perm]
    cnt = int((np.asarray(adj) > 0).sum(1).min()) + 1   # + self
    if cnt > 2 * f:
        out = np.asarray(cns.trimmed_mean_mix(a, {"w": w}, f)["w"])
        pout = np.asarray(cns.trimmed_mean_mix(pa, {"w": pw}, f)["w"])
        np.testing.assert_array_equal(pout, out[perm])
    out = np.asarray(cns.median_mix(a, {"w": w})["w"])
    pout = np.asarray(cns.median_mix(pa, {"w": pw})["w"])
    np.testing.assert_array_equal(pout, out[perm])


@given(seed=st.integers(0, 99), m=st.integers(2, 8), d=st.integers(1, 8),
       kind=st.sampled_from(["ring", "complete", "star", "line"]))
@settings(**SETTINGS)
def test_trimmed_f0_is_masked_neighbor_mean_bitwise(seed, m, d, kind):
    """f=0 trims nothing: the screen must reduce to the plain masked
    neighbor mean (unweighted, self included), summed in source order —
    bitwise, on any graph."""
    adj = tp.build_graph(kind, m)
    a = jnp.asarray(tp.metropolis_weights(adj), jnp.float32)
    w = jax.random.normal(jax.random.key(seed), (m, d))
    out = np.asarray(cns.trimmed_mean_mix(a, {"w": w}, 0)["w"])
    sup = np.asarray((a > 0) | jnp.eye(m, dtype=bool))
    ref = np.stack([
        np.asarray(jnp.where(jnp.asarray(sup[i][:, None]), w, 0.0)
                   .sum(0) / sup[i].sum()) for i in range(m)])
    np.testing.assert_array_equal(out, ref)


@given(seed=st.integers(0, 99), m=st.integers(4, 9), d=st.integers(1, 5),
       n_atk=st.integers(0, 1), atk_scale=st.floats(-1e6, 1e6))
@settings(**SETTINGS)
def test_robust_outputs_stay_in_honest_envelope(seed, m, d, n_atk,
                                                atk_scale):
    """With <= f arbitrary attacker values on a complete graph, every
    honest receiver's trimmed-mean and median output stays inside the
    coordinatewise honest min/max envelope."""
    a = jnp.asarray(tp.metropolis_weights(tp.complete_graph(m)), jnp.float32)
    w = np.asarray(_distinct_int_tree(seed, m, d)).copy()
    attackers = np.zeros(m, bool)
    attackers[:n_atk] = True
    w[attackers] = np.float32(atk_scale)
    hmin = w[~attackers].min(axis=0)
    hmax = w[~attackers].max(axis=0)
    wj = jnp.asarray(w)
    for mixed in (cns.trimmed_mean_mix(a, {"w": wj}, 1)["w"],
                  cns.median_mix(a, {"w": wj})["w"]):
        out = np.asarray(mixed)[~attackers]
        assert np.all(out >= hmin - 1e-4 * np.maximum(1, np.abs(hmin)))
        assert np.all(out <= hmax + 1e-4 * np.maximum(1, np.abs(hmax)))
