"""Adversarial fault-injection suite: Byzantine attacks vs robust gossip.

The headline demonstrations pin the paper-level claim: each injected
attack (sign-flip, scaled-noise) drives PLAIN gossip past the fig-3
tolerance (honest max error to w*), while every robust screening variant
(coordinatewise trimmed mean, coordinatewise median, clipped gossip)
keeps the honest servers converged under the SAME attack at f below the
breakdown point — and with f=0 the trimmed-mean path is bitwise the
unprotected 'gossip' path.  Also covered here: the attack-injection
machinery (ByzantineSchedule codes through drop/rejoin surgery,
engine determinism), the trace-driven participation round trip, and the
refusal surface (physical wire, push-sum, breakdown point, non-dynamic
configs, malformed specs)."""

import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzantineAttack, ByzantineSchedule, DFLConfig,
                        FLTopology, ParticipationSchedule, FaultSchedule,
                        apply_byzantine, build_dfl_epoch_step,
                        diurnal_trace, init_dfl_state,
                        load_participation_trace, make_backend, make_engine,
                        save_participation_trace, trimmed_mean_mix)
from repro.core import consensus as cns
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd

# fig-3 tolerance: honest servers within 0.05 of w* and in consensus
FIG3_ERR = 0.05
FIG3_DIS = 1e-3

# calibrated fast-tier sizes: ~1s per 40-epoch run, plain gossip under
# sign_flip:0.125 lands at err~2.0, the robust variants at err~0.004
M, N, T_C, T_S, EPOCHS = 8, 3, 15, 8, 40
GAMMA = 1.5 / (9.0 * T_C)


def _setup(seed=0):
    topo = FLTopology(num_servers=M, clients_per_server=N, t_client=T_C,
                      t_server=T_S, graph_kind="complete")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.0),
                                seed=seed)
    return topo, task


def _run(consensus_mode, byz, *, epochs=EPOCHS, seed=0, faults=None):
    """Run the engine; return (honest max err to w*, honest disagreement,
    raw server params)."""
    topo, task = _setup(seed)
    opt = sgd(GAMMA)
    engine = make_engine(topo, task["loss_fn"], opt,
                         consensus_mode=consensus_mode, byzantine=byz,
                         faults=faults)
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), opt,
                           jax.random.key(seed))
    state, _ = engine.run(state, epochs, task["batch_fn"])
    servers = np.asarray(state.client_params[:, 0])
    honest = np.ones(M, bool)
    if byz is not None:
        honest = byz.codes(0, tuple(range(M)), M) == 0
    h = servers[honest]
    err = float(np.linalg.norm(h - task["w_star"], axis=-1).max())
    dis = float(np.linalg.norm(h - h.mean(0), axis=-1).max())
    return err, dis, servers


# ---------------------------------------------------------------------------
# headline: attacks break plain gossip, not the robust variants
# ---------------------------------------------------------------------------


def test_sign_flip_breaks_plain_gossip_but_not_trimmed_or_clipped():
    """1 of 8 servers sign-flipping drives plain gossip far past the fig-3
    tolerance; trimmed-mean AND clipped gossip both converge under the
    exact same attack (f=1 < breakdown point on the complete graph)."""
    byz = ByzantineSchedule.parse("sign_flip:0.125")
    err_plain, _, _ = _run("gossip", byz)
    assert err_plain > FIG3_ERR, (
        f"sign-flip should break plain gossip, got err={err_plain}")
    for mode in ("trimmed_mean:1", "clipped"):
        err, dis, _ = _run(mode, byz)
        assert err < FIG3_ERR, f"{mode} under sign-flip: err={err}"
        assert dis < FIG3_DIS, f"{mode} under sign-flip: dis={dis}"


def test_scaled_noise_breaks_plain_gossip_but_not_median():
    byz = ByzantineSchedule.parse("scaled_noise:0.125:10.0")
    err_plain, _, _ = _run("gossip", byz)
    assert err_plain > FIG3_ERR
    err, dis, _ = _run("median", byz)
    assert err < FIG3_ERR and dis < FIG3_DIS


def test_no_attack_baselines_converge():
    """All four paths meet the fig-3 tolerance with no attacker — the
    robust screens cost accuracy only under attack, not in the clear."""
    for mode in ("gossip", "trimmed_mean:1", "median", "clipped"):
        err, dis, _ = _run(mode, None, epochs=EPOCHS)
        assert err < FIG3_ERR, f"{mode} no-attack err={err}"
        assert dis < FIG3_DIS, f"{mode} no-attack dis={dis}"


def test_trimmed_f0_engine_bitwise_identical_to_plain_gossip():
    """trimmed_mean:0 requests no screening, so the whole engine run must
    be bit-identical to the unprotected 'gossip' run."""
    _, _, s_plain = _run("gossip", None, epochs=6)
    _, _, s_trim = _run("trimmed_mean:0", None, epochs=6)
    np.testing.assert_array_equal(s_plain, s_trim)


def test_inlier_shift_stays_inside_honest_envelope():
    """The colluding inlier-shift attack lands INSIDE the coordinatewise
    honest min/max envelope (it cannot be screened as an outlier), yet the
    trimmed mean's output also stays inside that envelope — the attack
    biases, it cannot explode."""
    key = jax.random.key(3)
    tree = {"w": jax.random.normal(key, (M, 5))}
    codes = jnp.asarray([1, 0, 0, 0, 1, 0, 0, 0], jnp.int32)
    atk = (ByzantineAttack("inlier_shift", 0.25, scale=0.8),)
    attacked = apply_byzantine(tree, codes, jax.random.key(9), atk)
    honest = np.asarray(codes) == 0
    ref = np.asarray(tree["w"])
    out = np.asarray(attacked["w"])
    hmin = ref[honest].min(axis=0)
    hmax = ref[honest].max(axis=0)
    np.testing.assert_array_equal(out[honest], ref[honest])
    assert np.all(out[~honest] >= hmin - 1e-6)
    assert np.all(out[~honest] <= hmax + 1e-6)
    assert np.any(out[~honest] != ref[~honest])  # it did act
    a = jnp.asarray(np.ones((M, M)) / M, jnp.float32)
    mixed = np.asarray(trimmed_mean_mix(a, attacked, 1)["w"])
    assert np.all(mixed >= hmin - 1e-6) and np.all(mixed <= hmax + 1e-6)


# ---------------------------------------------------------------------------
# attacker bookkeeping: codes, surgery, determinism
# ---------------------------------------------------------------------------


def test_attacker_codes_follow_original_ids_through_surgery():
    """codes() is keyed to ORIGINAL server ids: dropping an unrelated
    server must not shift which physical server attacks."""
    byz = ByzantineSchedule.parse("sign_flip:0.25", seed=7)
    full = tuple(range(M))
    base = byz.codes(0, full, M)
    attackers = {full[i] for i in range(M) if base[i] != 0}
    victim = next(i for i in full if i not in attackers)
    alive = tuple(i for i in full if i != victim)
    after = byz.codes(0, alive, M)
    assert {alive[i] for i in range(len(alive)) if after[i] != 0} == attackers


def test_engine_run_with_byzantine_and_surgery_is_deterministic():
    """Same seeds, same program: two in-process runs with an attack AND a
    drop/rejoin fault are bitwise identical."""
    byz = ByzantineSchedule.parse("sign_flip:0.125", seed=1)
    faults = FaultSchedule.parse("drop:2:3,rejoin:4:3")
    _, _, s1 = _run("trimmed_mean:1", byz, epochs=6, faults=faults)
    _, _, s2 = _run("trimmed_mean:1", byz, epochs=6, faults=faults)
    np.testing.assert_array_equal(s1, s2)


@pytest.mark.slow
def test_engine_byzantine_seed_determinism_across_processes(tmp_path):
    """The full adversarial run (ByzantineSchedule + drop/rejoin surgery)
    reproduces bitwise across two fresh interpreter processes."""
    prog = textwrap.dedent("""
        import sys, numpy as np, jax, jax.numpy as jnp
        from repro.core import (ByzantineSchedule, FLTopology, FaultSchedule,
                                init_dfl_state, make_engine)
        from repro.data import RegressionSpec, make_regression_task
        from repro.optim import sgd
        topo = FLTopology(num_servers=8, clients_per_server=3, t_client=15,
                          t_server=8, graph_kind="complete")
        task = make_regression_task(topo, RegressionSpec(heterogeneity=0.0),
                                    seed=0)
        opt = sgd(1.5 / (9.0 * 15))
        engine = make_engine(topo, task["loss_fn"], opt,
                             consensus_mode="trimmed_mean:1",
                             byzantine=ByzantineSchedule.parse(
                                 "sign_flip:0.125", seed=1),
                             faults=FaultSchedule.parse(
                                 "drop:2:3,rejoin:4:3"))
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), opt,
                               jax.random.key(0))
        state, _ = engine.run(state, 6, task["batch_fn"])
        np.save(sys.argv[1], np.asarray(state.client_params))
    """)
    outs = []
    for tag in ("a", "b"):
        out = tmp_path / f"{tag}.npy"
        env = dict(os.environ,
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(os.path.dirname(__file__), os.pardir,
                                     "src")]
                       + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
        subprocess.run([sys.executable, "-c", prog, str(out)], check=True,
                       env=env)
        outs.append(np.load(out))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# trace-driven participation: round trip + replay semantics
# ---------------------------------------------------------------------------


def test_trace_roundtrip_bitwise_and_expected_rate(tmp_path):
    trace = diurnal_trace(12, 4, 3, seed=5)
    path = tmp_path / "avail.jsonl"
    save_participation_trace(path, trace)
    loaded = load_participation_trace(path)
    np.testing.assert_array_equal(trace, loaded)
    sched = ParticipationSchedule(kind="trace", trace=loaded)
    for epoch in range(24):                     # wraps past the trace length
        np.testing.assert_array_equal(
            sched.mask(epoch, 4, 3), trace[epoch % 12].astype(np.float32))
    assert sched.expected_rate(3) == pytest.approx(float(trace.mean()))
    empirical = np.mean([sched.mask(e, 4, 3) for e in range(12)])
    assert empirical == pytest.approx(float(trace.mean()))


def test_trace_jsonl_is_line_per_epoch(tmp_path):
    trace = diurnal_trace(3, 2, 2, seed=0)
    path = tmp_path / "t.jsonl"
    save_participation_trace(path, trace)
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [r["epoch"] for r in lines] == [0, 1, 2]
    np.testing.assert_array_equal(np.asarray(lines[1]["mask"]), trace[1])


def test_diurnal_trace_respects_min_per_server():
    trace = diurnal_trace(40, 5, 4, base=0.05, amplitude=0.0,
                          min_per_server=1, seed=2)
    assert trace.shape == (40, 5, 4)
    assert int(trace.sum(axis=2).min()) >= 1


def test_trace_schedule_drives_engine():
    topo, task = _setup()
    trace = diurnal_trace(6, M, N, seed=3)
    part = ParticipationSchedule(kind="trace", trace=trace)
    opt = sgd(GAMMA)
    engine = make_engine(topo, task["loss_fn"], opt, participation=part)
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), opt,
                           jax.random.key(0))
    _, hist = engine.run(state, 6, task["batch_fn"])
    expect = [float(trace[e].mean()) for e in range(6)]
    np.testing.assert_allclose(hist["participation"], expect, atol=1e-6)


def test_rate_trace_bernoulli_semantics_and_expected_rate():
    """A float-rate (E, M, N) trace is interpreted as per-epoch Bernoulli
    RATES: the mask is the deterministic (seed, epoch) draw against the
    epoch's rate row, and expected_rate is the exact mean of the rates."""
    rng = np.random.default_rng(11)
    trace = rng.uniform(0.0, 1.0, size=(5, 3, 4)).astype(np.float32)
    sched = ParticipationSchedule(kind="trace", trace=trace, seed=7)
    for epoch in range(10):                     # wraps past the trace length
        row = trace[epoch % 5]
        draw = np.random.default_rng((7, epoch)).random((3, 4))
        np.testing.assert_array_equal(
            sched.mask(epoch, 3, 4),
            (draw < row.astype(np.float64)).astype(np.float32))
        # deterministic in (seed, epoch): independent of call order
        np.testing.assert_array_equal(sched.mask(epoch, 3, 4),
                                      sched.mask(epoch, 3, 4))
    assert sched.expected_rate(4) == pytest.approx(
        float(np.asarray(trace, np.float64).mean()))
    # the empirical mean over many epochs concentrates on the rate mean
    empirical = np.mean([sched.mask(e, 3, 4) for e in range(400)])
    assert empirical == pytest.approx(float(trace.mean()), abs=0.03)


def test_rate_trace_jsonl_roundtrip_float32(tmp_path):
    """Rate traces round-trip through the JSONL log f32-bitwise, while a
    0/1 availability log keeps the original integer-list format."""
    rng = np.random.default_rng(3)
    rates = rng.uniform(0.0, 1.0, size=(4, 2, 3)).astype(np.float32)
    path = tmp_path / "rates.jsonl"
    save_participation_trace(path, rates)
    loaded = load_participation_trace(path)
    assert loaded.dtype == np.float32
    np.testing.assert_array_equal(loaded, rates)          # bitwise
    # loaded trace drives the same masks as the in-memory one
    a = ParticipationSchedule(kind="trace", trace=rates, seed=1)
    b = ParticipationSchedule(kind="trace", trace=loaded, seed=1)
    for epoch in range(4):
        np.testing.assert_array_equal(a.mask(epoch, 2, 3),
                                      b.mask(epoch, 2, 3))
    # binary logs stay integer lists (byte-stable interchange format)
    binary = diurnal_trace(3, 2, 2, seed=0)
    bpath = tmp_path / "binary.jsonl"
    save_participation_trace(bpath, binary)
    rec = json.loads(bpath.read_text().splitlines()[0])
    assert all(isinstance(v, int) for row in rec["mask"] for v in row)
    assert load_participation_trace(bpath).dtype == np.uint8


def test_rate_trace_rejects_out_of_range():
    bad = np.full((2, 2, 2), 1.5, np.float32)
    with pytest.raises(ValueError, match="Bernoulli"):
        ParticipationSchedule(kind="trace", trace=bad)
    with pytest.raises(ValueError, match="Bernoulli"):
        ParticipationSchedule(kind="trace",
                              trace=-0.1 * np.ones((1, 2, 2), np.float32))


# ---------------------------------------------------------------------------
# refusal surface
# ---------------------------------------------------------------------------


def test_physical_wire_refuses_robust_inner():
    topo, _ = _setup()
    a = topo.mixing_matrix()
    inner = make_backend("trimmed_mean:1", a, T_S)
    from repro.comm.compressors import make_compressor
    with pytest.raises(ValueError, match="plaintext"):
        cns.CompressedBackend(inner, make_compressor("int8"),
                              wire="physical")


def test_push_sum_refuses_robust_modes():
    topo, task = _setup()
    for mode in ("trimmed_mean:1", "median", "clipped"):
        cfg = DFLConfig(topology=topo, consensus_mode=mode,
                        mixing="push_sum")
        with pytest.raises(ValueError, match="ratio-consensus"):
            build_dfl_epoch_step(cfg, task["loss_fn"], sgd(GAMMA))


def test_byzantine_requires_dynamic_engine():
    topo, task = _setup()
    cfg = DFLConfig(topology=topo, consensus_mode="gossip",
                    byzantine=ByzantineSchedule.parse("sign_flip:0.125"))
    with pytest.raises(ValueError, match="dynamic"):
        build_dfl_epoch_step(cfg, task["loss_fn"], sgd(GAMMA))


def test_trimmed_mean_breakdown_point_fails_fast():
    """On a 3-server line graph the endpoints see only 2 values; f=1
    discards 2 per coordinate — past the breakdown point at build time."""
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=2,
                      t_server=2, graph_kind="line")
    with pytest.raises(ValueError, match="breakdown"):
        make_backend("trimmed_mean:1", topo.mixing_matrix(), 2)


def test_schedule_validation_needs_an_honest_server():
    with pytest.raises(ValueError):
        ByzantineSchedule.parse("sign_flip:1.0").validate(4)
    ByzantineSchedule.parse("sign_flip:0.5").validate(4)  # 2 of 4 is fine


def test_parse_rejects_malformed_specs():
    for bad in ("warp:0.1", "sign_flip", "sign_flip:x",
                "sign_flip:0.1:y", "sign_flip:2.0",
                "inlier_shift:0.1:3.0"):
        with pytest.raises(ValueError):
            ByzantineSchedule.parse(bad)
    for bad_mode in ("trimmed_mean:x", "median:3", "clipped:0",
                     "clipped:x"):
        with pytest.raises(ValueError):
            make_backend(bad_mode, np.ones((4, 4)) / 4, 2)


def test_trace_schedule_shape_and_kind_validation(tmp_path):
    trace = diurnal_trace(4, 3, 2, seed=0)
    sched = ParticipationSchedule(kind="trace", trace=trace)
    with pytest.raises(ValueError, match="resized"):
        sched.mask(0, 5, 2)
    with pytest.raises(ValueError):
        ParticipationSchedule(kind="trace")          # trace missing
    with pytest.raises(ValueError):
        ParticipationSchedule(kind="bernoulli", rate=0.5, trace=trace)
    path = tmp_path / "bad.jsonl"
    path.write_text('{"epoch": 1, "mask": [[1]]}\n')
    with pytest.raises(ValueError):
        load_participation_trace(path)               # not epoch-contiguous
