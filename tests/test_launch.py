"""Launcher invariants: plans, sharding resolver, roofline parser,
supported-pair registry.  (The actual 512-device compiles live in
launch/dryrun.py — these tests cover the pure-python layers.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch import roofline as rl
from repro.launch.mesh import FLMeshSpec
from repro.launch.plans import PLANS, plan_for
from repro.launch.specs import supported_pairs


def test_every_arch_has_a_plan():
    for arch_id in ARCH_IDS:
        assert plan_for(arch_id) is not None


@pytest.mark.parametrize("multi_pod", [False, True])
def test_plans_fill_the_mesh(multi_pod):
    target = 512 if multi_pod else 256
    for plan in PLANS.values():
        spec = plan.fl_spec(multi_pod)
        assert spec.total_devices() == target, plan.arch_id
        if multi_pod:
            assert spec.num_servers % 2 == 0 or spec.num_servers == 2


def test_plans_param_budget():
    """params/device (bf16/f32 per plan) fits alongside grads in 16 GB."""
    for arch_id in ARCH_IDS:
        plan = plan_for(arch_id)
        cfg = get_arch(arch_id)
        spec = plan.fl_spec(False)
        bytes_per = 2 if plan.param_dtype == "bfloat16" else 4
        per_dev = cfg.param_count() * bytes_per / (spec.fsdp * spec.tp)
        assert per_dev * 2 < 16e9, (arch_id, per_dev / 1e9)


def test_supported_pairs_count():
    pairs = supported_pairs()
    assert len(pairs) == 34          # 10 x 3 + 4 long-context archs
    longs = [a for a, s in pairs if s == "long_500k"]
    assert sorted(longs) == sorted([
        "mixtral_8x22b", "gemma2_27b", "jamba_1_5_large_398b",
        "mamba2_780m"])


def test_collective_parser_on_synthetic_hlo():
    hlo = """
ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256]{1,0} all-gather(%a), dimensions={0}
  %ar = bf16[64,64]{1,0} all-reduce(%b)
  %rs = f32[32]{0} reduce-scatter(%c), dimensions={0}
  %cp = u16[16,16]{1,0} collective-permute(%d)
  %a2a = f32[8,8]{1,0} all-to-all(%e), dimensions={1}
}
"""
    stats = rl.collective_bytes(hlo)
    assert stats.bytes_by_kind["all-gather"] == 128 * 256 * 4
    assert stats.bytes_by_kind["all-reduce"] == 64 * 64 * 2 * 2    # x2
    assert stats.bytes_by_kind["reduce-scatter"] == 32 * 4
    assert stats.bytes_by_kind["collective-permute"] == 16 * 16 * 2
    assert stats.bytes_by_kind["all-to-all"] == 8 * 8 * 4
    assert stats.total_bytes == sum(stats.bytes_by_kind.values())


def test_roofline_terms():
    meta = {"arch": "qwen3_1_7b", "shape": "train_4k", "multi_pod": False,
            "M": 2, "N": 1, "R": 8, "TP": 16, "per_client_batch": 128,
            "t_client": 2, "t_server": 25, "params": int(2e9),
            "dtype": "bfloat16", "active_params": 1e9}
    cost = {"flops": 1e12, "bytes accessed": 1e11}
    coll = rl.CollectiveStats({"all-gather": int(2e10)}, {"all-gather": 3})
    rep = rl.roofline(meta, 256, cost, coll)
    tokens = 2 * 2 * 1 * 128 * 4096          # T_C * M * N * b * seq
    assert rep.model_flops == pytest.approx(6 * 1e9 * tokens)
    assert rep.compute_s == pytest.approx(6 * 1e9 * tokens / 256 /
                                          rl.PEAK_FLOPS)
    assert rep.collective_s == pytest.approx(2e10 / rl.ICI_BW)
    assert rep.hlo_flops_per_device == 1e12


def test_sharding_resolver_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shd
    mesh = jax.sharding.Mesh(
        np.array(jax.devices() * 1).reshape(1, 1, 1, 1),
        ("server", "client", "replica", "model"))
    params = {
        # DFL layout: every leaf carries (M, N) client axes
        "embed": jnp.zeros((2, 2, 128, 64)),
        "stack": {"w_q": jnp.zeros((2, 2, 9, 64, 16, 8))},
        "norm": {"scale": jnp.zeros((2, 2, 64))},
    }
    specs = shd.fl_param_specs(params, mesh)
    assert specs["embed"][0] == "server" and specs["embed"][1] == "client"
    assert specs["stack"]["w_q"][0] == "server"
    assert specs["stack"]["w_q"][1] == "client"
    assert specs["norm"]["scale"][:2] == ("server", "client")


def test_mesh_specs_validate():
    spec = FLMeshSpec(num_servers=4, clients_per_server=4, fsdp=1, tp=16)
    assert spec.total_devices() == 256
    assert spec.devices_per_client == 16
