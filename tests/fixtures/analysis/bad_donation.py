"""Seeded violation: jax.jit of an epoch step without donation."""
import jax

from repro.core import build_dfl_epoch_step


def undonated(cfg, loss_fn, opt):
    return jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))   # two copies
