"""Seeded violations: host syncs inside compiled bodies."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def decorated_item(x):
    return x + x.mean().item()            # .item() inside a jitted body


def jitted_at_call_site():
    def step(w, g):
        lr = float(g)                     # float() of a traced operand
        return w - lr * g
    return jax.jit(step)


def scanned_asarray():
    def body(carry, x):
        return carry + np.asarray(x), None   # host materialise in scan body
    return jax.lax.scan(body, jnp.zeros(()), jnp.arange(3.0))
