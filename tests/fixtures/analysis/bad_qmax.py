"""Seeded violation: raw division by qmax at a scale site."""
import jax.numpy as jnp


def scales(absmax, qmax):
    return jnp.where(absmax > 0, absmax / qmax, 1.0)    # the 1-ulp trap


class Quantizer:
    qmax = 127.0

    def scale(self, absmax):
        return absmax / self.qmax                       # attribute form
