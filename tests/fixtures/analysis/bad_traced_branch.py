"""Seeded violations: Python control flow on traced operands."""
import jax


@jax.jit
def branch_on_traced(x, y):
    if x > 0:                      # freezes at trace time
        return x + y
    return x - y


def while_on_traced():
    def body(w, tol):
        while w.sum() > tol:       # trace-time loop on traced values
            w = w * 0.5
        return w
    return jax.jit(body)
