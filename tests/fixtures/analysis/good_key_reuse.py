"""Clean twin: every sampler gets a fresh key via split / fold_in."""
import jax


def straight_line_split():
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.normal(ka, (4,))
    b = jax.random.uniform(kb, (4,))
    return a, b


def loop_fold_in():
    key = jax.random.key(1)
    outs = []
    for i in range(3):
        k = jax.random.fold_in(key, i)
        outs.append(jax.random.normal(k, (2,)))
    return outs


def loop_over_split():
    outs = []
    for k in jax.random.split(jax.random.key(2), 3):
        outs.append(jax.random.normal(k, (2,)))
    return outs
