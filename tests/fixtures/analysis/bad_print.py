"""Fixture: bare print() calls in library code (print-in-library)."""


def log_progress(epoch, loss):
    print(f"epoch {epoch}: loss={loss:.4f}")      # finding 1


def debug_dump(tree):
    for leaf in tree:
        print(leaf)                               # finding 2
    return tree


def suppressed_without_reason(x):
    print(x)  # repro: ignore[print-in-library]
    return x
