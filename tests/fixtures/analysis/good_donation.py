"""Clean twin: the carried state is donated."""
import jax

from repro.core import build_dfl_epoch_step


def donated(cfg, loss_fn, opt):
    return jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt),
                   donate_argnums=(0,))


def unrelated_jit(fn):
    return jax.jit(fn)        # not an epoch step: no donation contract
