"""Clean twin: host reads happen OUTSIDE the compiled step."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def all_traced(x):
    return x + x.mean()


def host_read_outside():
    step = jax.jit(lambda w, g: w - 0.1 * g)
    w = step(jnp.ones(()), jnp.ones(()))
    return float(w), np.asarray(w), w.item()    # outside jit: fine


def scan_stays_traced():
    def body(carry, x):
        return carry + x, None
    return jax.lax.scan(body, jnp.zeros(()), jnp.arange(3.0))
