"""Suppression-grammar fixture: one reasoned (silences), one bare
(surfaces as bare-suppression), one naming an unknown rule."""


def reasoned(absmax, qmax):
    return absmax / qmax  # repro: ignore[qmax-division]: fixture exercising the reasoned-suppression path


def bare(absmax, qmax):
    return absmax / qmax  # repro: ignore[qmax-division]


def unknown(x):
    return x  # repro: ignore[no-such-rule]: reason present but rule unknown
