"""Seeded violation: PRNG key consumed twice, and sampled in a loop."""
import jax


def straight_line_reuse():
    key = jax.random.key(0)
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))        # reuse: same draws as `a`'s stream
    return a, b


def loop_invariant_key():
    key = jax.random.key(1)
    outs = []
    for _ in range(3):
        outs.append(jax.random.normal(key, (2,)))   # identical every iteration
    return outs
