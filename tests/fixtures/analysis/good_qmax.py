"""Clean twin: multiply by the reciprocal CONSTANT (the PR-6 idiom)."""
import jax.numpy as jnp


def scales(absmax, qmax):
    return jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)


class Quantizer:
    qmax = 127.0

    def scale(self, absmax):
        return absmax * (1.0 / self.qmax)


def unrelated_division(x, total):
    return x / total            # not a qmax site
