"""Seeded violations: mutable default + dead EpochSchedule operand."""


def accumulate(x, seen=[]):          # shared across every call
    seen.append(x)
    return seen


def epoch_step_dynamic(state, batches, sched):
    return state, batches            # sched never read: mask/mixing dropped
