"""Clean twin: None default; schedule operand threaded (or disavowed)."""


def accumulate(x, seen=None):
    seen = [] if seen is None else seen
    seen.append(x)
    return seen


def epoch_step_dynamic(state, batches, sched):
    mask = sched.mask
    return state, (batches, mask)


def static_step(state, batches, _sched):
    return state, batches            # underscore: explicitly unused
