"""Clean twin: structural tests and lax control flow."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def structural_and_lax(x, y):
    if y is None:                              # structural: pytree shape
        return x
    return jnp.where(x > 0, x + y, x - y)      # traced select


@functools.partial(jax.jit, static_argnames=("mode",))
def static_branch(x, mode):
    if mode == "double":                       # static_argnames: exempt
        return x * 2
    return x
