"""Clean twin: output routed through repro.obs sinks, or print suppressed
with a reason at a genuine CLI surface."""
import logging

from repro.obs import ConsoleSink, MetricsHub


def log_progress(epoch, record):
    hub = MetricsHub([ConsoleSink()])
    hub.observe_epoch(epoch, record)
    hub.close()


def debug_dump(tree):
    logging.getLogger(__name__).debug("tree: %s", tree)
    return tree


def cli_entry(msg):
    print(msg)  # repro: ignore[print-in-library]: CLI entry point output
