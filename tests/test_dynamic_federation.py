"""The dynamic-federation engine: partial participation, time-varying
graphs, fault schedules — and its exact degeneration to the static paper
setting (all-ones mask + static A == seed ``gossip``, bitwise)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, EpochSchedule, FaultEvent, FaultSchedule,
                        FLTopology, ParticipationSchedule, SigmaTracker,
                        TopologySchedule, build_dfl_epoch_step,
                        init_dfl_state, make_engine, masked_server_mean)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.data import RegressionSpec, make_regression_task
from repro.optim import momentum, sgd


def _setup(m=5, n=5, t_c=15, t_s=8, seed=0, heterogeneity=0.0):
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(
        heterogeneity=heterogeneity), seed=seed)
    return topo, task["loss_fn"], task["batches"], task["w_star"]


# ---------------------------------------------------------------------------
# exact degeneration to the static paper setting
# ---------------------------------------------------------------------------


def test_all_ones_mask_static_graph_reproduces_gossip_bitwise():
    """Dynamic step with full participation + the static A must be the SAME
    program as the seed 'gossip' epoch step — bit-identical params."""
    topo, loss_fn, batches, _ = _setup()
    gamma = 1e-3
    opt = sgd(gamma)
    step_s = jax.jit(build_dfl_epoch_step(
        DFLConfig(topology=topo), loss_fn, opt))
    step_d = jax.jit(build_dfl_epoch_step(
        DFLConfig(topology=topo, dynamic=True), loss_fn, opt))
    st_s = init_dfl_state(DFLConfig(topology=topo), jnp.zeros((2,)), opt,
                          jax.random.key(0))
    st_d = st_s
    sched = EpochSchedule(
        jnp.ones((topo.num_servers, topo.clients_per_server), jnp.float32),
        jnp.asarray(topo.mixing_matrix(), jnp.float32))
    for _ in range(4):
        st_s, m_s = step_s(st_s, batches)
        st_d, m_d = step_d(st_d, batches, sched)
    np.testing.assert_array_equal(np.asarray(st_s.client_params),
                                  np.asarray(st_d.client_params))
    np.testing.assert_array_equal(np.asarray(m_s.loss), np.asarray(m_d.loss))


def test_constant_tv_schedule_matches_gossip_scan(rng_key):
    """gossip_scan_tv with T_S copies of A == gossip_scan(A, ·, T_S)."""
    m, t_s = 6, 9
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    tree = {"w": jax.random.normal(rng_key, (m, 4, 3)),
            "b": jax.random.normal(jax.random.fold_in(rng_key, 1), (m, 7))}
    stack = jnp.broadcast_to(a, (t_s,) + a.shape)
    out_tv = cns.gossip_scan_tv(stack, tree)
    out_ref = cns.gossip_scan(a, tree, t_s)
    for l1, l2 in zip(jax.tree.leaves(out_tv), jax.tree.leaves(out_ref)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_tv_gossip_preserves_mean_under_varying_graphs(rng_key):
    """Each round's A_t is doubly stochastic, so any schedule of distinct
    graphs still fixes the server mean."""
    m = 5
    mats = [tp.metropolis_weights(tp.ring_graph(m)),
            tp.metropolis_weights(tp.line_graph(m)),
            tp.metropolis_weights(tp.complete_graph(m))]
    stack = jnp.asarray(np.stack(mats), jnp.float32)
    w = jax.random.normal(rng_key, (m, 11))
    out = cns.gossip_scan_tv(stack, {"w": w})["w"]
    np.testing.assert_allclose(np.asarray(w.mean(0)), np.asarray(out.mean(0)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# masked aggregation (Eq. 4 over the participating set)
# ---------------------------------------------------------------------------


def test_masked_mean_is_subset_mean(rng_key):
    m, n = 4, 6
    x = jax.random.normal(rng_key, (m, n, 3))
    mask_np = (np.random.default_rng(0).random((m, n)) < 0.5)
    mask_np[:, 0] = True                       # keep every server non-empty
    out = masked_server_mean({"w": x}, jnp.asarray(mask_np, jnp.float32))["w"]
    for i in range(m):
        ref = np.asarray(x)[i][mask_np[i]].mean(axis=0)
        np.testing.assert_allclose(np.asarray(out[i]), ref, rtol=1e-6,
                                   atol=1e-6)


def test_masked_mean_iid_participants_preserve_server_mean(rng_key):
    """When every client of a server holds the SAME model (the IID broadcast
    state), the masked mean equals the server mean for every mask —
    participation sampling introduces no bias."""
    m, n = 3, 5
    base = jax.random.normal(rng_key, (m, 1, 4))
    x = jnp.broadcast_to(base, (m, n, 4))
    for seed in range(3):
        mask = (np.random.default_rng(seed).random((m, n)) < 0.4)
        out = masked_server_mean({"w": x}, jnp.asarray(mask, jnp.float32))["w"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(base[:, 0]),
                                   rtol=1e-6, atol=1e-6)


def test_fully_idle_server_carries_model_through_epoch():
    """mask row of zeros: the server's aggregate falls back to the broadcast
    model it started the epoch with."""
    topo, loss_fn, batches, _ = _setup(m=3, n=2, t_c=5, t_s=4)
    opt = sgd(1e-3)
    cfg = DFLConfig(topology=topo, dynamic=True, consensus_mode="none")
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, jnp.ones((2,)), opt, jax.random.key(0))
    mask = np.ones((3, 2), np.float32)
    mask[1] = 0.0                                # server 1 fully idle
    sched = EpochSchedule(jnp.asarray(mask),
                          jnp.asarray(topo.mixing_matrix(), jnp.float32))
    new_state, _ = step(state, batches, sched)
    # with consensus off, idle server 1 must still hold w_0 exactly
    np.testing.assert_array_equal(
        np.asarray(new_state.client_params[1]),
        np.asarray(state.client_params[1]))
    # the training servers moved
    assert np.abs(np.asarray(new_state.client_params[0])
                  - np.asarray(state.client_params[0])).max() > 1e-6


def test_non_participant_data_never_influences_result():
    """Masking client (0, 1) out makes its batch contents irrelevant — same
    result with its data replaced by garbage (participation isolation)."""
    topo, loss_fn, (bx, by), _ = _setup(m=2, n=3, t_c=5, t_s=4)
    opt = sgd(1e-3)
    cfg = DFLConfig(topology=topo, dynamic=True)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    mask = np.ones((2, 3), np.float32)
    mask[0, 1] = 0.0
    sched = EpochSchedule(jnp.asarray(mask),
                          jnp.asarray(topo.mixing_matrix(), jnp.float32))
    out1, _ = step(state, (bx, by), sched)
    bad_bx = bx.at[:, 0, 1].set(1e6)             # garbage in masked slot
    bad_by = by.at[:, 0, 1].set(-1e6)
    out2, _ = step(state, (bad_bx, bad_by), sched)
    np.testing.assert_array_equal(np.asarray(out1.client_params),
                                  np.asarray(out2.client_params))


def test_carry_forward_preserves_optimizer_state():
    """Stateful optimizers: a non-participant's momentum buffer must freeze
    while the shared step count still advances."""
    topo, loss_fn, batches, _ = _setup(m=2, n=2, t_c=3, t_s=2)
    opt = momentum(1e-3)
    cfg = DFLConfig(topology=topo, dynamic=True)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    mask = np.asarray([[1.0, 0.0], [1.0, 1.0]], np.float32)
    sched = EpochSchedule(jnp.asarray(mask),
                          jnp.asarray(topo.mixing_matrix(), jnp.float32))
    new_state, _ = step(state, batches, sched)
    vel_old = np.asarray(state.opt_state.velocity)
    vel_new = np.asarray(new_state.opt_state.velocity)
    np.testing.assert_array_equal(vel_new[0, 1], vel_old[0, 1])  # frozen
    assert np.abs(vel_new[0, 0] - vel_old[0, 0]).max() > 0       # trained
    assert int(new_state.opt_state.count) == topo.t_client


# ---------------------------------------------------------------------------
# participation / topology schedules (host side)
# ---------------------------------------------------------------------------


def test_participation_schedules_shapes_and_determinism():
    for sched in (ParticipationSchedule(),
                  ParticipationSchedule(kind="bernoulli", rate=0.3, seed=3),
                  ParticipationSchedule(kind="fixed_k", k=2, seed=3),
                  ParticipationSchedule(kind="round_robin", k=2)):
        m1 = sched.mask(7, 4, 5)
        m2 = sched.mask(7, 4, 5)
        np.testing.assert_array_equal(m1, m2)       # deterministic in epoch
        assert m1.shape == (4, 5) and m1.dtype == np.float32
        assert set(np.unique(m1)) <= {0.0, 1.0}
        assert (m1.sum(axis=1) >= 1).all()          # min_per_server=1
    with pytest.raises(ValueError):
        ParticipationSchedule(kind="bogus")
    with pytest.raises(ValueError):
        ParticipationSchedule(kind="fixed_k")        # k missing


def test_round_robin_covers_all_clients():
    sched = ParticipationSchedule(kind="round_robin", k=2)
    seen = np.zeros(6, bool)
    for e in range(3):
        seen |= sched.mask(e, 2, 6)[0].astype(bool)
    assert seen.all()


def test_topology_schedule_emits_valid_mixing():
    topo = FLTopology(num_servers=6, clients_per_server=2, t_client=5,
                      t_server=3, graph_kind="ring")
    for sched in (TopologySchedule(),
                  TopologySchedule(kind="edge_drop", drop_prob=0.5, seed=1),
                  TopologySchedule(kind="straggler", weaken=0.9, n_weak=2,
                                   seed=1)):
        for epoch in range(4):
            a = sched.mixing(topo, epoch)
            tp.check_mixing_matrix(a)                # doubly stochastic
            # a degraded graph contracts slower but must still contract
            assert tp.sigma_a(a, 50) < 0.1
    with pytest.raises(ValueError):
        TopologySchedule(kind="bogus")


def test_sigma_tracker_matches_matrix_power():
    a = tp.metropolis_weights(tp.ring_graph(5))
    tr = SigmaTracker(5)
    for p in range(1, 4):
        got = tr.update(a, 6)
        assert got == pytest.approx(tp.sigma_a(a, 6 * p), abs=1e-12)
    # product form agrees with topology.sigma_product
    mats = [a, tp.metropolis_weights(tp.line_graph(5))]
    tr2 = SigmaTracker(5)
    for mat in mats:
        last = tr2.update(mat, 3)
    assert last == pytest.approx(tp.sigma_product(mats, 3), abs=1e-12)


def test_fault_schedule_parse_and_validation():
    fs = FaultSchedule.parse("drop:5:2, rejoin:9:2")
    assert fs.at(5) == (FaultEvent(5, "drop", 2),)
    assert fs.at(9) == (FaultEvent(9, "rejoin", 2),)
    assert fs.at(7) == ()
    assert fs.last_epoch == 9
    assert FaultSchedule.parse("").events == ()
    with pytest.raises(ValueError):
        FaultEvent(1, "explode", 0)


# ---------------------------------------------------------------------------
# end-to-end scenarios
# ---------------------------------------------------------------------------


def test_partial_participation_converges():
    """Bernoulli(0.5) sampling still lands near w* (slower, not broken)."""
    topo, loss_fn, batches, w_star = _setup(t_c=20, t_s=10)
    gamma = 0.4 / (9.0 * topo.t_client)

    def batch_fn(epoch, alive):
        ids = np.asarray(alive)
        return batches[0][:, ids], batches[1][:, ids]

    engine = make_engine(topo, loss_fn, sgd(gamma),
                         participation=ParticipationSchedule(
                             kind="bernoulli", rate=0.5, seed=3))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    state, hist = engine.run(state, 60, batch_fn)
    servers = np.asarray(state.client_params[:, 0])
    err = float(np.linalg.norm(servers - w_star, axis=-1).max())
    assert err < 0.3, err
    assert 0.2 < np.mean(hist["participation"]) < 0.8


def test_edge_drop_schedule_converges():
    """Per-epoch degraded (but repaired-to-connected) graphs still reach
    consensus near w*."""
    topo, loss_fn, batches, w_star = _setup(t_c=20, t_s=10)
    gamma = 0.4 / (9.0 * topo.t_client)

    def batch_fn(epoch, alive):
        ids = np.asarray(alive)
        return batches[0][:, ids], batches[1][:, ids]

    engine = make_engine(topo, loss_fn, sgd(gamma),
                         topology_schedule=TopologySchedule(
                             kind="edge_drop", drop_prob=0.4, seed=5))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    state, hist = engine.run(state, 60, batch_fn)
    servers = np.asarray(state.client_params[:, 0])
    err = float(np.linalg.norm(servers - w_star, axis=-1).max())
    assert err < 0.3, err
    assert hist["disagreement"][-1] < 1e-2
    assert hist["sigma_prod"][-1] < 1e-6


def test_fault_drop_and_rejoin_converges():
    """Mid-run server failure AND recovery: drop server 2 at epoch 8, rejoin
    at epoch 20 (it re-enters with the survivor mean and its own clients'
    data), and the 5-server federation still converges to the full-data w*.
    Extends the static drop-only test in test_dfl_convergence.py."""
    topo, loss_fn, batches, w_star = _setup(t_c=20, t_s=10)
    gamma = 0.35 / (9.0 * topo.t_client)

    def batch_fn(epoch, alive):
        ids = np.asarray(alive)
        return batches[0][:, ids], batches[1][:, ids]

    engine = make_engine(topo, loss_fn, sgd(gamma),
                         faults=FaultSchedule((FaultEvent(8, "drop", 2),
                                               FaultEvent(20, "rejoin", 2))))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    state, hist = engine.run(state, 60, batch_fn)
    assert engine.alive == [0, 1, 3, 4, 2]
    assert hist["num_servers"][7] == 5.0
    assert hist["num_servers"][8] == 4.0
    assert hist["num_servers"][20] == 5.0
    servers = np.asarray(state.client_params[:, 0])
    err = float(np.linalg.norm(servers - w_star, axis=-1).max())
    assert err < 0.3, err
    assert hist["disagreement"][-1] < 1e-2


def test_engine_rejects_bad_fault_events():
    topo, loss_fn, batches, _ = _setup(m=3, n=2, t_c=3, t_s=2)
    gamma = 1e-3

    def batch_fn(epoch, alive):
        ids = np.asarray(alive)
        return batches[0][:, ids], batches[1][:, ids]

    # ids outside the ORIGINAL federation fail at CONSTRUCTION, not mid-run:
    # a fresh server has no data shard (batch_fn slices by original id)
    for kind in ("drop", "rejoin"):
        with pytest.raises(ValueError, match="ORIGINAL"):
            make_engine(topo, loss_fn, sgd(gamma),
                        faults=FaultSchedule((FaultEvent(0, kind, 7),)))
    # dropping a server twice is a runtime liveness error
    engine = make_engine(topo, loss_fn, sgd(gamma),
                         faults=FaultSchedule((FaultEvent(0, "drop", 2),
                                               FaultEvent(0, "drop", 2))))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                           jax.random.key(0))
    with pytest.raises(ValueError, match="not alive"):
        engine.run(state, 1, batch_fn)
    # rejoin of an alive server is also rejected
    engine2 = make_engine(topo, loss_fn, sgd(gamma),
                          faults=FaultSchedule((FaultEvent(0, "rejoin", 1),)))
    state2 = init_dfl_state(engine2.cfg, jnp.zeros((2,)), sgd(gamma),
                            jax.random.key(0))
    with pytest.raises(ValueError, match="already alive"):
        engine2.run(state2, 1, batch_fn)
    # direct fresh-id rejoin (the old crash path: _next_id minting) is gone
    engine3 = make_engine(topo, loss_fn, sgd(gamma))
    state3 = init_dfl_state(engine3.cfg, jnp.zeros((2,)), sgd(gamma),
                            jax.random.key(0))
    with pytest.raises(ValueError, match="ORIGINAL"):
        engine3._rejoin(state3, None)
    with pytest.raises(ValueError, match="ORIGINAL"):
        engine3._rejoin(state3, 5)


def test_dynamic_chebyshev_consumes_traced_a_p():
    """Chebyshev now rides the dynamic engine: the per-epoch spectral
    estimate arrives as a traced operand (``EpochSchedule.lam2``, computed
    host-side by the engine via ``topology.lambda_2``), so the semi-
    iterative recursion serves time-varying graphs through ONE compiled
    program — the formerly-prohibited combination."""
    topo = FLTopology(num_servers=4, clients_per_server=2, t_client=3,
                      t_server=9, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    engine = make_engine(topo, task["loss_fn"], sgd(1e-3),
                         consensus_mode="chebyshev",
                         topology_schedule=TopologySchedule(
                             kind="edge_drop", drop_prob=0.3, seed=5))
    assert engine._needs_spectral
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    state, hist = engine.run(state, 4, task["batch_fn"])
    assert np.isfinite(hist["loss"]).all()
    # the accelerated rounds still contract server disagreement
    assert hist["disagreement"][-1] < 5e-2


def test_chebyshev_backend_traced_matches_reference():
    """ChebyshevBackend.mix with a TRACED (A_p, lam2) pair equals the
    host-side gossip_chebyshev recursion on the same concrete matrix, for
    per-epoch matrices the backend was NOT built with."""
    m, t_s = 5, 9
    base = tp.metropolis_weights(tp.ring_graph(m))
    backend = cns.make_backend("chebyshev", base, t_s)
    assert backend.supports_traced and backend.needs_spectral
    tree = {"w": jax.random.normal(jax.random.key(1), (m, 6))}
    mixed_jit = jax.jit(backend.mix)
    for a_np in (base, tp.metropolis_weights(tp.complete_graph(m)),
                 tp.metropolis_weights(tp.line_graph(m))):
        lam2 = tp.lambda_2(a_np)
        a = jnp.asarray(a_np, jnp.float32)
        out = mixed_jit(tree, a, jnp.float32(lam2))
        ref = cns.gossip_chebyshev(a, tree, backend.rounds, lam2)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(ref["w"]),
                                   rtol=2e-5, atol=2e-5)
        # no lam2 operand: the in-graph eigendecomposition fallback
        out_fb = jax.jit(lambda t, ap: backend.mix(t, ap))(tree, a)
        np.testing.assert_allclose(np.asarray(out_fb["w"]),
                                   np.asarray(ref["w"]),
                                   rtol=2e-4, atol=2e-4)


def test_regression_task_batch_fn_validates_ids():
    """jax gather clamps out-of-range indices; the batch_fn must raise
    instead of silently feeding a duplicate of another server's shard."""
    from repro.data import make_regression_task
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=2,
                      t_server=1)
    task = make_regression_task(topo)
    task["batch_fn"](0, (0, 2))                   # valid subset is fine
    with pytest.raises(ValueError, match="out of range"):
        task["batch_fn"](0, (0, 1, 2, 7))


@pytest.mark.parametrize("mode", ["collapsed", "exact_mean"])
def test_dynamic_consensus_modes_agree_with_static(mode):
    """Dynamic 'collapsed' traces A^{T_S} in-program; with the static A it
    must match the static-mode epoch step (fp32 tolerance)."""
    topo, loss_fn, batches, _ = _setup(m=4, n=3, t_c=6, t_s=5)
    opt = sgd(1e-3)
    step_s = jax.jit(build_dfl_epoch_step(
        DFLConfig(topology=topo, consensus_mode=mode), loss_fn, opt))
    step_d = jax.jit(build_dfl_epoch_step(
        DFLConfig(topology=topo, consensus_mode=mode, dynamic=True),
        loss_fn, opt))
    state = init_dfl_state(DFLConfig(topology=topo), jnp.zeros((2,)), opt,
                           jax.random.key(0))
    sched = EpochSchedule(
        jnp.ones((4, 3), jnp.float32),
        jnp.asarray(topo.mixing_matrix(), jnp.float32))
    out_s, _ = step_s(state, batches)
    out_d, _ = step_d(state, batches, sched)
    np.testing.assert_allclose(np.asarray(out_s.client_params),
                               np.asarray(out_d.client_params),
                               rtol=2e-5, atol=2e-6)


def test_pipeline_server_ids_slicing():
    """FLDataPipeline emits only the alive servers' shards, keyed by
    ORIGINAL identity (a rejoined server gets its own streams back)."""
    from repro.data import DataConfig, FLDataPipeline
    topo = FLTopology(num_servers=4, clients_per_server=2, t_client=3,
                      t_server=1)
    cfg = DataConfig(seq_len=16, per_client_batch=2, vocab_size=64, seed=0)
    pipe = FLDataPipeline(topo, cfg)
    full = pipe.epoch_batches(0)
    sub = pipe.epoch_batches(0, server_ids=(0, 2, 3))
    np.testing.assert_array_equal(np.asarray(full["tokens"][:, [0, 2, 3]]),
                                  np.asarray(sub["tokens"]))
    with pytest.raises(ValueError, match="out of range"):
        pipe.epoch_batches(0, server_ids=(0, 9))
