"""Per-architecture smoke tests (assignment deliverable (f)).

Each of the 10 assigned architectures instantiates a REDUCED member of the
same family (<=2-3 layers, d_model<=512, <=4 experts) and runs, on CPU:
  * a forward pass      — output shape + finiteness
  * one DFL train step  — loss finite, params updated, disagreement -> 0
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.core import DFLConfig, FLTopology, build_dfl_epoch_step, init_dfl_state
from repro.models import transformer as tf
from repro.optim import sgd

from conftest import arch_params

B, S = 2, 32

# The two double-jit equivalence suites keep smaller fast subsets than the
# conftest default (each param costs two full jit compiles).
ARCH_PARAMS = arch_params(ARCH_IDS)
TRAIN_PARAMS = arch_params(ARCH_IDS,
                           ("smollm_360m", "mixtral_8x22b", "mamba2_780m"))
MICRO_PARAMS = arch_params(ARCH_IDS, ("smollm_360m", "mixtral_8x22b"))


def _batch(cfg, key, lead=(B,), seq=S):
    batch = {"tokens": jax.random.randint(key, lead + (seq,), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.frontend is not None:
        name = ("patch_embeds" if cfg.frontend.kind == "vision_patches"
                else "frames")
        n = cfg.frontend.num_tokens or seq
        batch[name] = jax.random.normal(
            jax.random.fold_in(key, 7), lead + (n, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_forward_shapes_and_finite(arch_id, rng_key):
    cfg = get_smoke(arch_id)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = tf.init_params(rng_key, cfg)
    batch = _batch(cfg, rng_key)
    logits, aux = jax.jit(lambda p, b: tf.forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, batch["tokens"].shape[-1],
                            cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", TRAIN_PARAMS)
def test_dfl_train_step(arch_id, rng_key):
    """One full DFL epoch (2 servers x 2 clients, T_C=2, T_S=3)."""
    cfg = get_smoke(arch_id)
    topo = FLTopology(num_servers=2, clients_per_server=2, t_client=2,
                      t_server=3)
    dfl_cfg = DFLConfig(topology=topo)
    opts = tf.ApplyOptions(remat=False)
    loss_fn = tf.make_loss_fn(cfg, opts, loss_chunk=16)
    opt = sgd(1e-2)
    step = jax.jit(build_dfl_epoch_step(dfl_cfg, loss_fn, opt))
    params = tf.init_params(rng_key, cfg)
    state = init_dfl_state(dfl_cfg, params, opt, jax.random.key(1))
    batch = _batch(cfg, rng_key, lead=(topo.t_client, 2, 2, B), seq=S)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics.loss).all())
    assert bool(jnp.isfinite(metrics.server_disagreement))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(new_state.client_params),
        jax.tree.leaves(state.client_params)))
    assert delta > 0
    # post-broadcast client copies within a server are identical
    cp = new_state.client_params
    leaf = jax.tree.leaves(cp)[0]
    np.testing.assert_array_equal(np.asarray(leaf[:, 0]),
                                  np.asarray(leaf[:, 1]))


@pytest.mark.parametrize("arch_id", MICRO_PARAMS)
def test_grad_microbatching_matches_full_batch(arch_id, rng_key):
    """grad_microbatches=2 == full-batch gradient (Eq. 3 equivalence)."""
    cfg = get_smoke(arch_id)
    topo = FLTopology(num_servers=2, clients_per_server=1, t_client=1,
                      t_server=1)
    # drop-free MoE: capacity-based drops depend on the (micro)batch
    # boundaries, so only the no-drop path is exactly batch-size-invariant
    opts = tf.ApplyOptions(remat=False, moe_no_drop=True)
    loss_fn = tf.make_loss_fn(cfg, opts, loss_chunk=16)
    opt = sgd(1e-2)
    params = tf.init_params(rng_key, cfg)
    batch = _batch(cfg, rng_key, lead=(1, 2, 1, 4), seq=S)

    outs = []
    for micro in (1, 2):
        dfl_cfg = DFLConfig(topology=topo, grad_microbatches=micro)
        step = jax.jit(build_dfl_epoch_step(dfl_cfg, loss_fn, opt))
        state = init_dfl_state(dfl_cfg, params, opt, jax.random.key(1))
        new_state, _ = step(state, batch)
        outs.append(new_state.client_params)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_loss_decreases_smollm(rng_key):
    """A few DFL epochs on synthetic LM data actually reduce the loss."""
    from repro.launch.train import train
    res = train("smollm-360m", servers=2, clients=2, t_client=3, t_server=3,
                epochs=4, seq_len=64, per_client_batch=2, gamma=0.1)
    hist = res["history"]["loss"]
    assert hist[-1] < hist[0], hist
    assert res["history"]["disagreement"][-1] < 1e-3
